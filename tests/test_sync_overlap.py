"""Staleness-1 overlapped sync (--sync-overlap) and its satellites.

The overlap round is a ROTATION of the barrier round: round k applies
the consensus carried from round k-1's collective, then issues round
k's collective before its inner scan.  R overlap rounds + one flush
must therefore reproduce R barrier rounds:

 * f32 local: BIT-identical (state and per-round losses), including
   across an lr-drop boundary.
 * int8 error-feedback sync: matches the barrier int8 trajectory to
   float tolerance for both the jnp codec and the fused
   apply+quantize Pallas kernel; the EF residual telescopes the same.
 * resume: checkpoints are PRE-flush; restoring one and continuing
   re-applies the carried consensus itself — bit-identical to never
   having stopped.
 * 8-device shard_map (subprocess): replica-only mesh bit-identical to
   the sharded barrier round; composed FSDP x TP mesh to tolerance.

Satellite regressions: the token-stream split=True fix (disjoint key
blocks; split=False bit-compatible with the legacy interleave), the
round stager threading split, checkpoint restore naming the offending
leaf on shape/dtype drift, and the replicas-vs-mesh SystemExit.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import ParleConfig
from repro.core import parle, registry
from repro.data.synthetic import (TokenStream, make_round_batch_fn,
                                  replica_batches)
from repro.kernels import ref as kref


def _loss(p, b):
    return jnp.mean((p["w"] @ p["m"] - b["t"]) ** 2), ()


def _params(key):
    return {"w": jax.random.normal(key, (8, 16)) * 0.1,
            "m": jax.random.normal(jax.random.fold_in(key, 1), (16, 4)) * 0.1}


def _round_batches(key, L, n):
    return {"t": jax.random.normal(key, (L, n, 8, 4))}


def _cfg(**kw):
    return ParleConfig(n_replicas=2, L=3, lr=0.05, lr_inner=0.05,
                       batches_per_epoch=5, lr_drop_steps=(4,),
                       lr_drop_factor=0.5, **kw)   # schedule crosses round 2


def _run(cfg, rounds=3, use_kernel=False, flush=False):
    algo = registry.get("parle")
    state = parle.dealias_state(algo.init(_params(jax.random.PRNGKey(0)),
                                          cfg))
    round_fn = algo.make_round_fn(_loss, cfg, use_kernel=use_kernel)
    losses = []
    for r in range(rounds):
        rb = _round_batches(jax.random.PRNGKey(10 + r), cfg.L,
                            cfg.n_replicas)
        state, m = round_fn(state, rb)
        losses.append(np.asarray(m["losses"]))
    if flush:
        state = algo.make_round_flush_fn(cfg)(state)
    return state, np.concatenate(losses)


def _assert_states(sa, sb, exact=True):
    for name in ("x", "y", "z", "v_x", "v_y"):
        for a, b in zip(jax.tree_util.tree_leaves(getattr(sa, name)),
                        jax.tree_util.tree_leaves(getattr(sb, name))):
            if exact:
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                              err_msg=name)
            else:
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=2e-5, atol=1e-7,
                                           err_msg=name)
    assert int(sa.step) == int(sb.step)


def test_overlap_plus_flush_bit_identical_f32():
    s_bar, l_bar = _run(_cfg())
    s_ovl, l_ovl = _run(_cfg(sync_overlap=True), flush=True)
    np.testing.assert_array_equal(l_bar, l_ovl)   # per-round losses too
    _assert_states(s_bar, s_ovl, exact=True)


@pytest.mark.parametrize("use_kernel", (False, True))
def test_overlap_int8_error_feedback(use_kernel):
    """int8 EF sync under overlap: same trajectory as the barrier int8
    path (whose telescoping is regression-tested in test_sync_compress)
    — the overlap round quantizes the SAME payload x+e the barrier
    round would, so the residuals telescope identically."""
    s_bar, _ = _run(_cfg(sync_compress="int8"), use_kernel=use_kernel)
    s_ovl, _ = _run(_cfg(sync_compress="int8", sync_overlap=True),
                    use_kernel=use_kernel, flush=True)
    _assert_states(s_bar, s_ovl, exact=False)
    for a, b in zip(jax.tree_util.tree_leaves(s_bar.e),
                    jax.tree_util.tree_leaves(s_ovl.e)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=1e-6)


def test_overlap_round_boundary_resume(tmp_path):
    """Checkpoints are written PRE-flush; a resumed run re-enters the
    overlap loop, which applies the carried consensus itself."""
    cfg = _cfg(sync_overlap=True)
    algo = registry.get("parle")
    round_fn = algo.make_round_fn(_loss, cfg)
    state = parle.dealias_state(algo.init(_params(jax.random.PRNGKey(0)),
                                          cfg))
    for r in range(2):
        state, _ = round_fn(state, _round_batches(jax.random.PRNGKey(10 + r),
                                                  cfg.L, cfg.n_replicas))
    path = str(tmp_path / "mid.npz")
    ckpt.save(path, state, step=int(state.step), algo="parle")

    template = algo.init(_params(jax.random.PRNGKey(0)), cfg)
    resumed = parle.dealias_state(ckpt.restore(path, template, algo="parle"))
    resumed, _ = round_fn(resumed, _round_batches(jax.random.PRNGKey(12),
                                                  cfg.L, cfg.n_replicas))
    resumed = algo.make_round_flush_fn(cfg)(resumed)

    uninterrupted, _ = _run(cfg, rounds=3, flush=True)
    _assert_states(uninterrupted, resumed, exact=True)


def test_apply_quantize_kernel_matches_oracle():
    """The fused apply-stale-consensus + quantize kernel against its
    pure-jnp oracle (ref.parle_apply_quantize).  The kernel's fused
    arithmetic differs from the oracle's composition by ~1 ulp in x',
    so the int8 codes may flip by at most 1 where a rounding boundary
    sits within that ulp; floats compare at tight tolerance."""
    from repro.kernels import parle_update as pu
    key = jax.random.PRNGKey(5)
    R, M = 2, pu.BLOCK_ELEMS
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (R, M))
    z = x + 0.1 * jax.random.normal(ks[1], (R, M))
    v = 0.01 * jax.random.normal(ks[2], (R, M))
    c = jax.random.normal(ks[3], (M,))
    e = 0.005 * jax.random.normal(ks[4], (R, M))
    kw = dict(gamma_scale=0.9, inv_rho=0.5, lr=0.05, mu=0.9)
    want = kref.parle_apply_quantize(x, z, v, c, e, **kw)
    scalars = jnp.array([kw["gamma_scale"], kw["inv_rho"], kw["lr"],
                         kw["mu"]], jnp.float32)
    got = pu.parle_apply_quantize_flat(x, z, v, c, e, scalars,
                                       interpret=True)
    for w, g, name in zip(want, got, ("x", "v", "q", "s", "e")):
        w, g = np.asarray(w), np.asarray(g).reshape(np.asarray(w).shape)
        if name == "q":
            assert np.abs(w.astype(np.int32) - g.astype(np.int32)).max() <= 1
        else:
            np.testing.assert_allclose(w, g, rtol=1e-5, atol=1e-6,
                                       err_msg=name)


# ------------------------------------------------------------------
# Satellite regressions
# ------------------------------------------------------------------

def test_token_stream_split_actually_splits():
    """split=True partitions the PRNG key space (disjoint per-shard
    blocks); split=False keeps the legacy interleave bit-for-bit."""
    stream = TokenStream(vocab_size=512, seq_len=16, batch_size=2, seed=3)
    n = 2
    b_split = replica_batches(stream, 5, 2, n, split=True)
    b_plain = replica_batches(stream, 5, 2, n, split=False)
    # before the fix both modes produced identical batches
    assert not np.array_equal(np.asarray(b_split["tokens"]),
                              np.asarray(b_plain["tokens"]))
    # disjointness: across a window of steps, shard 0 and shard 1 never
    # draw the same batch (their key blocks are 2^20 apart)
    draws = [set(), set()]
    for s in range(8):
        b = replica_batches(stream, s, 2, n, split=True)
        for a in range(n):
            draws[a].add(np.asarray(b["tokens"][a]).tobytes())
    assert not (draws[0] & draws[1])
    # split=False replica a at step s is the unsharded stream at step
    # s*n + a — the pre-fix derivation, unchanged
    flat = TokenStream(vocab_size=512, seq_len=16, batch_size=2, seed=3)
    for a in range(n):
        np.testing.assert_array_equal(
            np.asarray(b_plain["tokens"][a]),
            np.asarray(flat.batch(5 * n + a)["tokens"]))


@pytest.mark.parametrize("split", (False, True))
def test_round_stager_matches_per_step_both_modes(split):
    stream = TokenStream(vocab_size=512, seq_len=16, batch_size=2, seed=3)
    L, n = 4, 3
    stage = make_round_batch_fn(stream, L, 2, n, split=split)
    staged = stage(8)
    for j in range(L):
        want = replica_batches(stream, 8 + j, 2, n, split=split)
        got = jax.tree.map(lambda x: x[j], staged)
        for k in want:
            np.testing.assert_array_equal(np.asarray(want[k]),
                                          np.asarray(got[k]))


def test_restore_names_offending_leaf(tmp_path):
    path = str(tmp_path / "t.npz")
    ckpt.save(path, {"a": jnp.zeros((4, 3)), "b": jnp.ones((2,))}, step=1)
    with pytest.raises(ValueError, match=r"leaf 'a'.*shape"):
        ckpt.restore(path, {"a": jnp.zeros((4, 2)), "b": jnp.ones((2,))})
    # f32 checkpoint into a bf16 template must not silently restore f32
    with pytest.raises(ValueError, match=r"leaf 'b'.*dtype"):
        ckpt.restore(path, {"a": jnp.zeros((4, 3)),
                            "b": jnp.ones((2,), jnp.bfloat16)})
    # and the reverse: bf16 bits on disk, f32 template
    ckpt.save(path, {"a": jnp.zeros((4, 3), jnp.bfloat16)}, step=1)
    with pytest.raises(ValueError, match=r"leaf 'a'.*bfloat16"):
        ckpt.restore(path, {"a": jnp.zeros((4, 3))})


def test_replicas_mesh_mismatch_exits():
    from repro.launch import train
    mesh = SimpleNamespace(shape={"replica": 2})
    args = SimpleNamespace(replicas=3, algo="parle")
    cfg = registry.get("parle").canonicalize_cfg(
        ParleConfig(n_replicas=3, batches_per_epoch=5))
    with pytest.raises(SystemExit, match="divisible"):
        train._validate_replicas(args, cfg, mesh, "replica")
    # entropy_sgd canonicalizes n -> 1: a replica:4 mesh must die with
    # the rewrite spelled out, not a divisibility error on n=1
    args = SimpleNamespace(replicas=4, algo="entropy_sgd")
    cfg = registry.get("entropy_sgd").canonicalize_cfg(
        ParleConfig(n_replicas=4, batches_per_epoch=5))
    with pytest.raises(SystemExit, match="canonicalizes"):
        train._validate_replicas(args, cfg,
                                 SimpleNamespace(shape={"replica": 4}),
                                 "replica")
    # flag-combination guards fire before any model is built
    with pytest.raises(SystemExit, match="round-fused"):
        train.main(["--sync-overlap"])
    with pytest.raises(SystemExit, match="no round-level sync"):
        train.main(["--sync-overlap", "--round-fused", "--algo",
                    "elastic_sgd"])


# ------------------------------------------------------------------
# 8-device shard_map overlap (subprocess; see test_round_fused)
# ------------------------------------------------------------------

_CHILD = textwrap.dedent("""
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    assert len(jax.devices()) == 8
    from repro.configs.base import ParleConfig
    from repro.core import parle
    from repro.launch.mesh import make_mesh_from_spec

    cfg = ParleConfig(n_replicas=8, L=3, lr=0.05, lr_inner=0.05,
                      batches_per_epoch=5)
    ocfg = dataclasses.replace(cfg, sync_overlap=True)
    key = jax.random.PRNGKey(0)

    def loss(p, b):
        return jnp.mean((p["w"] - b["t"]) ** 2), ()

    reps = {"w": jax.random.normal(key, (8, 6))}
    rbs = [{"t": jax.random.normal(jax.random.PRNGKey(1 + r), (3, 8, 1))}
           for r in range(3)]

    # replica-only mesh: overlap + flush is BIT-identical to the
    # sharded barrier round (same psum, same placement, rotated)
    mesh8 = make_mesh_from_spec("replica:8")
    st_b = parle.dealias_state(parle.init_from_replicas(reps, cfg))
    round_b = parle.make_sharded_round_fn(loss, cfg, mesh8)
    st_o = parle.dealias_state(parle.init_from_replicas(reps, ocfg))
    round_o = parle.make_sharded_overlap_round_fn(loss, ocfg, mesh8)
    for rb in rbs:
        st_b, m_b = round_b(st_b, rb)
        st_o, m_o = round_o(st_o, rb)
        np.testing.assert_array_equal(np.asarray(m_b["losses"]),
                                      np.asarray(m_o["losses"]))
    st_o = parle.make_flush_fn(ocfg)(st_o)
    for name in ("x", "y", "z", "v_x", "v_y"):
        np.testing.assert_array_equal(
            np.asarray(getattr(st_b, name)["w"]),
            np.asarray(getattr(st_o, name)["w"]), err_msg=name)
    assert int(st_o.step) == int(st_b.step) == 9
    print("OVERLAP_MANUAL_OK")

    # composed FSDP x TP mesh (split head + GSPMD inner scan): matches
    # the local barrier trajectory to float tolerance
    meshc = make_mesh_from_spec("replica:2,data:2,model:2")
    cfgc = ParleConfig(n_replicas=2, L=3, lr=0.05, lr_inner=0.05,
                       batches_per_epoch=5)
    ocfgc = dataclasses.replace(cfgc, sync_overlap=True)
    repsc = {"w": jax.random.normal(key, (2, 8, 16)) * 0.1,
             "m": jax.random.normal(jax.random.fold_in(key, 1),
                                    (2, 16, 4)) * 0.1}
    rbcs = [{"t": jax.random.normal(jax.random.PRNGKey(20 + r),
                                    (3, 2, 8, 4))} for r in range(3)]

    def lossc(p, b):
        return jnp.mean((p["w"] @ p["m"] - b["t"]) ** 2), ()

    st_l = parle.dealias_state(parle.init_from_replicas(repsc, cfgc))
    round_l = parle.make_round_fn(lossc, cfgc)
    st_c = parle.dealias_state(parle.init_from_replicas(repsc, ocfgc))
    round_c = parle.make_sharded_overlap_round_fn(lossc, ocfgc, meshc)
    for rb in rbcs:
        st_l, m_l = round_l(st_l, rb)
        st_c, m_c = round_c(st_c, rb)
        np.testing.assert_allclose(np.asarray(m_c["losses"]),
                                   np.asarray(m_l["losses"]), rtol=1e-5)
    st_c = parle.make_flush_fn(ocfgc)(st_c)
    np.testing.assert_allclose(np.asarray(st_c.x["w"]),
                               np.asarray(st_l.x["w"]),
                               rtol=1e-5, atol=1e-6)
    print("OVERLAP_COMPOSED_OK")
""")


def _run_child(code):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8")
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..", "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    env.setdefault("JAX_PLATFORMS", "cpu")
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=420)


@pytest.fixture(scope="module")
def overlap_child():
    return _run_child(_CHILD)


def test_sharded_overlap_replica_only_bit_identical(overlap_child):
    assert overlap_child.returncode == 0, \
        f"stdout:\n{overlap_child.stdout}\nstderr:\n{overlap_child.stderr}"
    assert "OVERLAP_MANUAL_OK" in overlap_child.stdout


def test_sharded_overlap_composed_mesh_tolerance(overlap_child):
    assert overlap_child.returncode == 0, \
        f"stdout:\n{overlap_child.stdout}\nstderr:\n{overlap_child.stderr}"
    assert "OVERLAP_COMPOSED_OK" in overlap_child.stdout
