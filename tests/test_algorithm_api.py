"""The unified Algorithm protocol + registry (core/algorithm.py).

Covers the ISSUE-2 acceptance surface:
  * registry round-trip: names() <-> the --algo CLI choices, and every
    registered object satisfies the protocol;
  * cross-algorithm structural equivalence through the new API
    (entropy_sgd == parle n=1; elastic_sgd/sgd sharded step == local
    step on an 8-device host mesh — in a subprocess, same rationale as
    test_distributed_sync.py);
  * the per-step vs per-L-steps communication claim from compiled HLO
    (launch/hlo_stats.py entry-computation scope);
  * checkpoint restore rejecting a mismatched algo name;
  * lr step-decay boundaries taking effect through the protocol.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import ParleConfig
from repro.core import parle, registry
from repro.core.algorithm import Algorithm


def quad_loss(params, batch):
    del batch
    return 0.5 * jnp.sum((params["w"] - 3.0) ** 2), ()


# ------------------------------------------------------------------
# Registry round-trip
# ------------------------------------------------------------------

def test_registry_names_match_cli_choices():
    from repro.launch.train import build_argparser
    ap = build_argparser()
    algo_action = next(a for a in ap._actions if a.dest == "algo")
    assert sorted(algo_action.choices) == registry.names()
    assert registry.names() == ["elastic_sgd", "entropy_sgd", "parle", "sgd"]


def test_registered_objects_satisfy_protocol():
    for name in registry.names():
        algo = registry.get(name)
        assert isinstance(algo, Algorithm), name
        assert algo.name == name
        # same instance on repeated lookup (registry, not factory)
        assert registry.get(name) is algo


def test_registry_rejects_unknown_name():
    with pytest.raises(KeyError, match="unknown algorithm"):
        registry.get("adamw")


def test_canonicalize_entropy_sgd_forces_n1():
    cfg = ParleConfig(n_replicas=5)
    c = registry.get("entropy_sgd").canonicalize_cfg(cfg)
    assert c.n_replicas == 1 and c.mode == "entropy_sgd"
    assert registry.get("parle").canonicalize_cfg(cfg).n_replicas == 5


# ------------------------------------------------------------------
# Cross-algorithm structural equivalence through the protocol
# ------------------------------------------------------------------

def test_entropy_sgd_equals_parle_n1_through_protocol():
    params = {"w": jnp.array([1.0, -2.0, 0.5])}
    cfg = ParleConfig(n_replicas=1, L=3, lr=0.1, lr_inner=0.1)
    batch = {"x": jnp.zeros((1, 1))}
    states = {}
    for name in ("entropy_sgd", "parle"):
        algo = registry.get(name)
        c = algo.canonicalize_cfg(cfg)
        st = algo.init(params, c)
        step = algo.make_step(quad_loss, c)
        for _ in range(7):
            st, m = step(st, batch)
        assert "loss" in m, name
        states[name] = st
    np.testing.assert_allclose(np.asarray(states["entropy_sgd"].x["w"]),
                               np.asarray(states["parle"].x["w"]), rtol=1e-7)


def test_deployable_matches_legacy_accessors():
    cfg = ParleConfig(n_replicas=3, L=2, batches_per_epoch=5)
    params = {"w": jnp.arange(4.0)}
    batch = {"x": jnp.zeros((3, 1))}
    for name, legacy in (("parle", parle.average_model),
                        ("elastic_sgd", lambda s: s.ref)):
        algo = registry.get(name)
        st = algo.init(params, algo.canonicalize_cfg(cfg))
        step = jax.jit(algo.make_step(quad_loss, algo.canonicalize_cfg(cfg)))
        for i in range(3):
            st, _ = step(st, batch)
        np.testing.assert_allclose(np.asarray(algo.deployable(st)["w"]),
                                   np.asarray(legacy(st)["w"]))


def test_diagnostics_shape():
    cfg = ParleConfig(n_replicas=2, batches_per_epoch=5)
    for name in registry.names():
        algo = registry.get(name)
        c = algo.canonicalize_cfg(cfg)
        st = algo.init({"w": jnp.ones(4)}, c)
        d = algo.diagnostics(st)
        assert isinstance(d, dict)
        assert all(isinstance(v, float) for v in d.values()), (name, d)
        if name in ("parle", "elastic_sgd"):        # replica axis exists
            assert {"overlap", "spread"} <= set(d)


# ------------------------------------------------------------------
# LR step-decay through the protocol (satellite: §4 schedules)
# ------------------------------------------------------------------

def lin_loss(params, batch):
    del batch
    return jnp.sum(params["w"]), ()         # grad == 1 everywhere


@pytest.mark.parametrize("name", ["parle", "elastic_sgd", "sgd"])
def test_lr_drop_boundaries_take_effect(name):
    """With momentum 0 and a constant unit gradient, the per-step
    parameter displacement IS the lr — so the drop boundary is visible
    exactly at lr_drop_steps."""
    algo = registry.get(name)
    cfg = algo.canonicalize_cfg(ParleConfig(
        n_replicas=1, L=1000, momentum=0.0, gamma0=1e9, rho0=1e9,
        lr=0.1, lr_inner=0.1, lr_drop_steps=(3,), lr_drop_factor=0.1))
    st = algo.init({"w": jnp.zeros(4)}, cfg)
    step = jax.jit(algo.make_step(lin_loss, cfg))
    batch = {"x": jnp.zeros((1, 1))}

    def main_iterate(s):
        return np.asarray(algo.deployable(s)["w"]) if name == "sgd" \
            else np.asarray(s.x["w"] if name == "elastic_sgd" else s.y["w"])

    prev = main_iterate(st).copy()
    deltas = []
    for i in range(6):
        st, _ = step(st, batch)
        cur = main_iterate(st)
        deltas.append(float(np.abs(cur - prev).mean()))
        prev = cur.copy()
    np.testing.assert_allclose(deltas[:3], [0.1] * 3, rtol=1e-5)
    np.testing.assert_allclose(deltas[3:], [0.01] * 3, rtol=1e-5)


def test_explicit_lr_schedule_overrides_cfg():
    algo = registry.get("sgd")
    cfg = algo.canonicalize_cfg(ParleConfig(
        n_replicas=1, momentum=0.0, lr=1.0, lr_drop_steps=(1,)))
    step = jax.jit(algo.make_step(lin_loss, cfg,
                                  lr_schedule=lambda k: 0.5))
    st = algo.init({"w": jnp.zeros(2)}, cfg)
    st, m = step(st, {"x": jnp.zeros((1, 1))})
    assert float(m["lr"]) == pytest.approx(0.5)


# ------------------------------------------------------------------
# Checkpoint stamping / validation
# ------------------------------------------------------------------

def test_checkpoint_rejects_mismatched_algo(tmp_path):
    cfg = ParleConfig(n_replicas=2, batches_per_epoch=5)
    algo = registry.get("parle")
    st = algo.init({"w": jnp.ones(4)}, algo.canonicalize_cfg(cfg))
    path = str(tmp_path / "ck.npz")
    ckpt.save(path, st, step=7, algo="parle")
    assert ckpt.saved_meta(path)["algo"] == "parle"
    # same algo: round-trips
    back = ckpt.restore(path, st, algo="parle")
    np.testing.assert_allclose(np.asarray(back.x["w"]),
                               np.asarray(st.x["w"]))
    # different algo: refused
    with pytest.raises(ValueError, match="written by algo 'parle'"):
        ckpt.restore(path, st, algo="elastic_sgd")
    # unstamped caller (legacy) still restores
    ckpt.restore(path, st)


# ------------------------------------------------------------------
# Sharded equivalence + the per-step HLO communication claim
# (8-device child interpreter; see test_distributed_sync.py for why)
# ------------------------------------------------------------------

_CHILD = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    assert len(jax.devices()) == 8, jax.devices()
    from repro.configs.base import ParleConfig
    from repro.core import registry
    from repro.launch.hlo_stats import collective_bytes
    from repro.launch.mesh import make_mesh_from_spec

    def loss(p, b):
        return 0.5 * jnp.sum((p["w"] - b["t"]) ** 2), ()

    mesh = make_mesh_from_spec("replica:8")
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (6,))}
    batch = {"t": jax.random.normal(jax.random.PRNGKey(1), (8, 1))}

    # ---- elastic_sgd / sgd: sharded step == local step ------------
    for name in ("elastic_sgd", "sgd"):
        algo = registry.get(name)
        cfg = algo.canonicalize_cfg(ParleConfig(
            n_replicas=8, L=3, lr=0.1, lr_inner=0.1, batches_per_epoch=5))
        st_l, st_s = algo.init(params, cfg), algo.init(params, cfg)
        f_l = jax.jit(algo.make_step(loss, cfg))
        f_s = algo.make_sharded_step(loss, cfg, mesh)
        for i in range(5):                  # crosses an L=3 scope decay
            st_l, m_l = f_l(st_l, batch)
            st_s, m_s = f_s(st_s, batch)
        for a, b in zip(jax.tree.leaves(st_l), jax.tree.leaves(st_s)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(float(m_l["loss"]), float(m_s["loss"]),
                                   rtol=1e-6)
        dep_l, dep_s = algo.deployable(st_l), algo.deployable(st_s)
        np.testing.assert_allclose(np.asarray(dep_l["w"]),
                                   np.asarray(dep_s["w"]), rtol=1e-6)
    print("SHARDED_EQ_OK")

    # ---- per-step vs per-L communication, from compiled HLO -------
    size = 4096
    per_step = {}
    for name in ("parle", "elastic_sgd"):
        algo = registry.get(name)
        cfg = algo.canonicalize_cfg(ParleConfig(n_replicas=8, L=25,
                                                batches_per_epoch=10))
        st = algo.init({"w": jnp.zeros((size,), jnp.float32)}, cfg)
        step = algo.make_sharded_step(loss, cfg, mesh)
        hlo = step.lower(st, {"t": jnp.zeros((8, 1), jnp.float32)}) \\
                  .compile().as_text()
        total = collective_bytes(hlo)["bytes"]["all-reduce"]
        entry = collective_bytes(hlo, scope="entry")["bytes"]["all-reduce"]
        # both steps carry one model-size all-reduce overall (+ loss pmean)
        assert size * 4 <= total <= size * 4 + 64, (name, total)
        per_step[name] = entry
    # elastic: the model-size all-reduce is UNCONDITIONAL (every step);
    # parle: only the scalar loss pmean is — Eq. 8d fires once per L
    assert per_step["elastic_sgd"] >= size * 4, per_step
    assert per_step["parle"] < size, per_step
    print("PER_STEP_HLO_OK", per_step)
""")


def _run_child(code):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8")
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..", "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    env.setdefault("JAX_PLATFORMS", "cpu")
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=300)


@pytest.fixture(scope="module")
def child_run():
    return _run_child(_CHILD)


def test_sharded_baselines_match_local_on_8_device_mesh(child_run):
    assert child_run.returncode == 0, \
        f"stdout:\n{child_run.stdout}\nstderr:\n{child_run.stderr}"
    assert "SHARDED_EQ_OK" in child_run.stdout


def test_elastic_all_reduce_is_per_step_parle_per_L(child_run):
    """ISSUE-2 acceptance: --algo elastic_sgd --mesh replica:N compiles
    to one model-size all-reduce PER STEP (entry computation), while
    Parle's one model-size all-reduce sits under the k%L conditional."""
    assert child_run.returncode == 0, \
        f"stdout:\n{child_run.stdout}\nstderr:\n{child_run.stderr}"
    assert "PER_STEP_HLO_OK" in child_run.stdout
