"""Checkpointing, data pipeline, partition specs, HLO collective parser."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.configs import ARCHS, ParleConfig, get_config, smoke_variant
from repro.core import parle
from repro.data.synthetic import TeacherTask, TokenStream, replica_batches
from repro.models.model import build_model
from repro.sharding import partition


# ------------------------------------------------------------------
# checkpoint
# ------------------------------------------------------------------

def test_checkpoint_roundtrip_params(tmp_path, key):
    cfg = smoke_variant(get_config("qwen2.5-3b"))
    model = build_model(cfg)
    params = model.init(key)
    path = str(tmp_path / "ck.npz")
    ckpt.save(path, params, step=17)
    zeros = jax.tree.map(jnp.zeros_like, params)
    restored = ckpt.restore(path, zeros)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ckpt.latest_step(path) == 17


def test_checkpoint_roundtrip_parle_state(tmp_path, key):
    pcfg = ParleConfig(n_replicas=2)
    state = parle.init({"w": jax.random.normal(key, (4, 3))}, pcfg)
    path = str(tmp_path / "state.npz")
    ckpt.save(path, state, step=3)
    zeros = jax.tree.map(jnp.zeros_like, state)
    restored = ckpt.restore(path, zeros)
    np.testing.assert_array_equal(np.asarray(restored.x["w"]),
                                  np.asarray(state.x["w"]))
    assert float(restored.scopes.gamma) == float(state.scopes.gamma)


# ------------------------------------------------------------------
# data
# ------------------------------------------------------------------

def test_token_stream_deterministic():
    s = TokenStream(vocab_size=97, seq_len=16, batch_size=4, seed=3)
    b1, b2 = s.batch(5), s.batch(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = s.batch(6)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))
    # labels are next-token shifted
    assert b1["tokens"].shape == (4, 16)
    assert b1["labels"].shape == (4, 16)


def test_token_stream_has_learnable_structure(key):
    """A bigram table achieves < ln(V) loss on the stream."""
    s = TokenStream(vocab_size=32, seq_len=64, batch_size=16, seed=0)
    b = s.batch(0)
    toks, labels = np.asarray(b["tokens"]), np.asarray(b["labels"])
    # structure: next == (prev*31+7) % V for ~quarter of positions
    # (the coin mixes base and rule streams; chance level is 1/V ~ 3%)
    hit = (labels == (toks * 31 + 7) % 32).mean()
    assert hit > 0.2


def test_replica_batches_stack_and_split():
    task = TeacherTask(num_train=256, num_test=32)
    b = replica_batches(task, 0, 16, 3, split=True)
    assert b["x"].shape == (3, 16, 64)
    b2 = replica_batches(task, 0, 16, 3, split=False)
    assert b2["x"].shape == (3, 16, 64)


def test_audio_stream_shapes():
    s = TokenStream(vocab_size=64, seq_len=16, batch_size=2, num_codebooks=4)
    b = s.batch(0)
    assert b["tokens"].shape == (2, 4, 16)


# ------------------------------------------------------------------
# partition specs
# ------------------------------------------------------------------

@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_pspecs_cover_all_leaves(arch, key):
    cfg = smoke_variant(get_config(arch))
    model = build_model(cfg)
    p_sds = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    specs = partition.param_pspecs(p_sds)
    flat_p = jax.tree.leaves(p_sds)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(
        x, jax.sharding.PartitionSpec))
    assert len(flat_p) == len(flat_s)
    for leaf, spec in zip(flat_p, flat_s):
        assert len(spec) <= len(leaf.shape), (leaf.shape, spec)


def test_stacked_blocks_get_layer_axis_none(key):
    cfg = smoke_variant(get_config("llama3-8b"))
    model = build_model(cfg)
    p_sds = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    specs = partition.param_pspecs(p_sds)
    wq_spec = specs["blocks"]["attn"]["wq"]
    assert wq_spec[0] is None          # scan axis unsharded
    assert "model" in wq_spec


# ------------------------------------------------------------------
# HLO collective parser (unit test on synthetic HLO text)
# ------------------------------------------------------------------

def test_collective_parser_on_synthetic_hlo():
    # hlo_stats, not dryrun: importing dryrun force-sets the 512-device
    # host platform, which must never happen inside this suite
    from repro.launch import hlo_stats as dryrun
    hlo = """
HloModule jit_step
  %p0 = f32[16,128]{1,0} parameter(0)
  %p1 = bf16[8,64]{1,0} parameter(1)
  %ar = f32[16,128]{1,0} all-reduce(%p0), replica_groups={}
  %ag = bf16[128,64]{1,0} all-gather(%p1), dimensions={0}
  %a2a = f32[16,128]{1,0} all-to-all(%ar), dimensions={0}
  ROOT %t = (f32[16,128]{1,0}) tuple(%a2a)
"""
    res = dryrun.collective_bytes(hlo)
    assert res["bytes"]["all-reduce"] == 16 * 128 * 4
    assert res["bytes"]["all-gather"] == 8 * 64 * 2
    assert res["bytes"]["all-to-all"] == 16 * 128 * 4
    assert res["counts"]["all-reduce"] == 1
    assert res["total_bytes"] == 16 * 128 * 4 * 2 + 8 * 64 * 2


def test_collective_parser_async_pairs_counted_once():
    from repro.launch import dryrun
    hlo = """
  %p0 = f32[4,4]{1,0} parameter(0)
  %ags = (f32[4,4]{1,0}, f32[8,4]{1,0}) all-gather-start(%p0), dimensions={0}
  %agd = f32[8,4]{1,0} all-gather-done(%ags)
"""
    res = dryrun.collective_bytes(hlo)
    assert res["counts"]["all-gather"] == 1
    assert res["bytes"]["all-gather"] == 4 * 4 * 4


def test_input_shapes_table():
    from repro.launch import specs
    assert set(specs.INPUT_SHAPES) == {"train_4k", "prefill_32k",
                                       "decode_32k", "long_500k"}
    assert specs.INPUT_SHAPES["long_500k"]["seq_len"] == 524_288
    # long_500k forces sub-quadratic attention for attention archs
    cfg = specs.adapt_for_shape(get_config("llama3-8b"), "long_500k")
    assert cfg.sliding_window == specs.LONG_CONTEXT_WINDOW
    cfg = specs.adapt_for_shape(get_config("mamba2-1.3b"), "long_500k")
    assert cfg.sliding_window == 0
