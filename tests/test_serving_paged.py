"""The paged serving engine's exact-match contract: paged == dense ==
naive greedy tokens across families (the dense engine is the oracle,
itself exact-matched against naive.py in test_serving_engine.py), plus
chunked long-prompt prefill, prefix sharing end-to-end, page-exhaustion
backpressure, the bounded prefill compile cache, and the honest
utilization stats.

Tier-1 runs the dense-family paths; the other families ride the slow
lane (and the CI serving lane, which overrides the tier-1 filter).
"""
import jax
import numpy as np
import pytest

from conftest import FAMILY_CONFIGS, family_params
from repro.models.model import build_model
from repro.serving import Engine, SamplingParams

GEN = 8
MAX_LEN = 32
MIXED_LENS = (5, 9, 12, 7)

_PARAMS = {}


def _params(cfg, key):
    if cfg.name not in _PARAMS:
        _PARAMS[cfg.name] = build_model(cfg).init(key)
    return _PARAMS[cfg.name]


def _request(cfg, key, i, T):
    kk = jax.random.fold_in(key, 1000 + i)
    shape = (cfg.num_codebooks, T) if cfg.family == "audio" else (T,)
    req = {"tokens": np.asarray(
        jax.random.randint(kk, shape, 0, cfg.vocab_size), np.int32)}
    if cfg.family == "vlm":
        req["patch_embeds"] = jax.random.normal(
            jax.random.fold_in(kk, 1), (cfg.num_patches, cfg.d_model))
    if cfg.family == "audio":
        req["cond"] = jax.random.normal(
            jax.random.fold_in(kk, 2), (cfg.cond_len, cfg.d_model))
    return req


def _run(cfg, params, reqs, *, paged, max_len=MAX_LEN, arrivals=None,
         gen=GEN, num_slots=2, **kw):
    eng = Engine(cfg, params, num_slots=num_slots, max_len=max_len,
                 decode_chunk=3, paged=paged, **kw)
    for i, r in enumerate(reqs):
        eng.submit(r["tokens"], max_new_tokens=gen, cond=r.get("cond"),
                   patch_embeds=r.get("patch_embeds"),
                   arrival=0 if arrivals is None else arrivals[i])
    return eng.run(), eng


# ------------------------------------------------------------------
# paged == dense exact match (the dense engine is the oracle)
# ------------------------------------------------------------------

@pytest.mark.parametrize("family", family_params())
def test_paged_matches_dense_exactly(family, key):
    """Greedy paged decode emits token-for-token what the dense engine
    emits — mixed prompt lengths, fewer slots than requests, prefill
    chunk smaller than the longest prompt (chunked prefill exercised)."""
    cfg = FAMILY_CONFIGS[family]
    params = _params(cfg, key)
    reqs = [_request(cfg, key, i, T) for i, T in enumerate(MIXED_LENS)]
    dense, _ = _run(cfg, params, reqs, paged=False)
    paged, eng = _run(cfg, params, reqs, paged=True, page_size=16,
                      prefill_chunk=8)
    for i in range(len(reqs)):
        np.testing.assert_array_equal(paged[i], dense[i], err_msg=f"req {i}")
    tp = eng.throughput()
    assert 0.0 < tp["slot_utilization"] <= 1.0
    assert tp["wasted_decode_tokens"] >= 0


@pytest.mark.parametrize("family", [
    "dense",
    pytest.param("hybrid", marks=pytest.mark.slow),
    pytest.param("vlm", marks=pytest.mark.slow),
    pytest.param("audio", marks=pytest.mark.slow),
])
def test_paged_kernel_matches_dense(family, key):
    """The Pallas paged-attention decode path (use_paged_kernel) stays
    token-exact against the dense engine."""
    cfg = FAMILY_CONFIGS[family]
    params = _params(cfg, key)
    reqs = [_request(cfg, key, i, T) for i, T in enumerate(MIXED_LENS[:2])]
    dense, _ = _run(cfg, params, reqs, paged=False)
    paged, _ = _run(cfg, params, reqs, paged=True, page_size=16,
                    prefill_chunk=8, use_paged_kernel=True)
    for i in range(len(reqs)):
        np.testing.assert_array_equal(paged[i], dense[i], err_msg=f"req {i}")


def test_paged_long_prompt_many_chunks(key):
    """A prompt spanning many prefill chunks and many pages."""
    cfg = FAMILY_CONFIGS["dense"]
    params = _params(cfg, key)
    T, max_len = 49, 64
    req = {"tokens": np.asarray(
        jax.random.randint(key, (T,), 0, cfg.vocab_size), np.int32)}
    dense, _ = _run(cfg, params, [req], paged=False, max_len=max_len)
    paged, eng = _run(cfg, params, [req], paged=True, max_len=max_len,
                      page_size=8, prefill_chunk=8)
    np.testing.assert_array_equal(paged[0], dense[0])
    assert eng.stats["prefill_chunks"] >= -(-T // 8)


def test_paged_staggered_arrivals_match(key):
    cfg = FAMILY_CONFIGS["dense"]
    params = _params(cfg, key)
    reqs = [_request(cfg, key, i, T) for i, T in enumerate(MIXED_LENS)]
    dense, _ = _run(cfg, params, reqs, paged=False)
    paged, _ = _run(cfg, params, reqs, paged=True, page_size=16,
                    prefill_chunk=8, arrivals=list(range(len(reqs))))
    for i in range(len(reqs)):
        np.testing.assert_array_equal(paged[i], dense[i], err_msg=f"req {i}")


def test_paged_sampling_topk1_matches_dense(key):
    cfg = FAMILY_CONFIGS["dense"]
    params = _params(cfg, key)
    reqs = [_request(cfg, key, i, T) for i, T in enumerate(MIXED_LENS[:2])]
    sp = SamplingParams(temperature=0.8, top_k=1)
    dense, _ = _run(cfg, params, reqs, paged=False, sampling=sp)
    paged, _ = _run(cfg, params, reqs, paged=True, page_size=16,
                    prefill_chunk=8, sampling=sp)
    for i in range(len(reqs)):
        np.testing.assert_array_equal(paged[i], dense[i])


# ------------------------------------------------------------------
# prefix sharing end-to-end
# ------------------------------------------------------------------

def test_prefix_sharing_hits_without_changing_tokens(key):
    """Staggered requests sharing a long system prompt: the later
    requests resume prefill past the shared pages (hit rate > 0) and
    still emit exactly the dense engine's tokens."""
    cfg = FAMILY_CONFIGS["dense"]
    params = _params(cfg, key)
    shared = np.asarray(jax.random.randint(key, (32,), 0, cfg.vocab_size),
                        np.int32)
    reqs = [{"tokens": np.concatenate([shared, np.asarray(
        jax.random.randint(jax.random.fold_in(key, i), (4,),
                           0, cfg.vocab_size), np.int32)])}
            for i in range(4)]
    # request 0 finishes its prefill (publishing pages) before the rest
    arrivals = [0, 6, 6, 6]
    dense, _ = _run(cfg, params, reqs, paged=False, max_len=64,
                    arrivals=arrivals, num_slots=4)
    paged, eng = _run(cfg, params, reqs, paged=True, max_len=64,
                      arrivals=arrivals, num_slots=4, page_size=16,
                      prefill_chunk=16)
    for i in range(len(reqs)):
        np.testing.assert_array_equal(paged[i], dense[i], err_msg=f"req {i}")
    assert eng.pool.prefix_hit_rate() > 0
    assert eng.pool.stats["prefix_hit_tokens"] == 3 * 32  # 2 pages x 3 reqs
    assert eng.throughput()["prefix_hit_rate"] > 0


def test_prefix_sharing_identical_prompt_cow(key):
    """Resubmitting an identical prompt reuses all full pages but still
    recomputes the last position (copy-on-extend of the final page) —
    outputs stay exact."""
    cfg = FAMILY_CONFIGS["dense"]
    params = _params(cfg, key)
    prompt = np.asarray(jax.random.randint(key, (32,), 0, cfg.vocab_size),
                        np.int32)
    reqs = [{"tokens": prompt}, {"tokens": prompt.copy()}]
    dense, _ = _run(cfg, params, reqs, paged=False, max_len=64,
                    arrivals=[0, 6])
    paged, eng = _run(cfg, params, reqs, paged=True, max_len=64,
                      arrivals=[0, 6], page_size=16, prefill_chunk=16)
    np.testing.assert_array_equal(paged[0], dense[0])
    np.testing.assert_array_equal(paged[1], dense[1])
    assert eng.pool.stats["cow_copies"] == 1
    assert eng.pool.stats["prefix_hit_tokens"] == 31   # prompt_len - 1


# ------------------------------------------------------------------
# backpressure
# ------------------------------------------------------------------

def test_page_exhaustion_backpressures_not_crashes(key):
    """A pool too small for all requests at once: admission waits for
    pages (requests queue in (arrival, uid) order) and every request
    still completes with the exact dense tokens."""
    cfg = FAMILY_CONFIGS["dense"]
    params = _params(cfg, key)
    reqs = [_request(cfg, key, i, T) for i, T in enumerate(MIXED_LENS)]
    dense, _ = _run(cfg, params, reqs, paged=False)
    # each request needs ceil((12+8)/16) <= 2 pages; 3 usable pages
    # -> at most one two-page resident plus one more, never all four
    paged, eng = _run(cfg, params, reqs, paged=True, num_slots=4,
                      page_size=16, prefill_chunk=8, num_pages=4,
                      prefix_share=False)
    for i in range(len(reqs)):
        np.testing.assert_array_equal(paged[i], dense[i], err_msg=f"req {i}")
    assert eng.pool.alloc.num_free == eng.pool.alloc.usable  # all returned


def test_submit_rejects_unserveable_request(key):
    """A single request whose worst case exceeds the whole pool must be
    rejected at submit time (it could never be admitted)."""
    cfg = FAMILY_CONFIGS["dense"]
    params = _params(cfg, key)
    eng = Engine(cfg, params, num_slots=2, max_len=MAX_LEN, paged=True,
                 page_size=16, num_pages=2)        # 1 usable page
    with pytest.raises(ValueError):
        eng.submit(np.arange(12, dtype=np.int32), max_new_tokens=GEN)


def test_paged_rejects_sliding_window(key):
    import dataclasses
    cfg = FAMILY_CONFIGS["dense"]
    swa = dataclasses.replace(cfg, sliding_window=16)
    params = _params(cfg, key)
    with pytest.raises(ValueError):
        Engine(swa, params, paged=True)


# ------------------------------------------------------------------
# bounded prefill compile cache (satellite 1)
# ------------------------------------------------------------------

def test_prefill_compile_cache_is_bucketed(key):
    """Many distinct prompt lengths compile at most O(log max_len)
    prefill programs — lengths bucket to the next power of two."""
    cfg = FAMILY_CONFIGS["dense"]
    params = _params(cfg, key)
    lens = list(range(3, 19))                      # 16 distinct lengths
    reqs = [{"tokens": np.asarray(
        jax.random.randint(jax.random.fold_in(key, i), (T,),
                           0, cfg.vocab_size), np.int32)}
            for i, T in enumerate(lens)]
    out, eng = _run(cfg, params, reqs, paged=False, gen=2)
    assert len(out) == len(lens)
    # buckets touched: 8, 16, 32 — never one program per length
    assert len(eng._prefills) <= 3


# ------------------------------------------------------------------
# utilization stats (satellite 2)
# ------------------------------------------------------------------

def test_throughput_reports_honest_utilization(key):
    """One long and one short request on two slots: the short slot goes
    idle, so utilization < 1 and the waste is positive and consistent."""
    cfg = FAMILY_CONFIGS["dense"]
    params = _params(cfg, key)
    eng = Engine(cfg, params, num_slots=2, max_len=MAX_LEN, decode_chunk=4)
    eng.submit(np.arange(5, dtype=np.int32), max_new_tokens=16)
    eng.submit(np.arange(5, dtype=np.int32) + 5, max_new_tokens=2)
    eng.run()
    tp = eng.throughput()
    s = eng.stats
    capacity = s["decode_steps"] * 2
    assert 0.0 < tp["slot_utilization"] < 1.0
    assert tp["wasted_decode_tokens"] == capacity - s["decode_tokens"]
    assert tp["slot_utilization"] == round(s["decode_tokens"] / capacity, 4)
