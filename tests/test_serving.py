"""Prefill + decode must agree with the full forward pass — per family,
including the sliding-window ring buffer."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import FAMILY_CONFIGS, family_params, make_batch
from repro.models.model import build_model

TOL = 2e-4


def _full_and_incremental(cfg, key, T=17, prefix=16):
    model = build_model(cfg)
    params = model.init(key)
    full = make_batch(cfg, key, batch=2, seq=T)
    logits_full, _ = model.apply(params, full)

    pre = dict(full)
    pre["tokens"] = full["tokens"][..., :prefix]
    cache = model.init_cache(params, 2, 32)
    lp, cache = model.prefill(params, pre, cache)
    step = dict(pre)
    step["tokens"] = full["tokens"][..., prefix:prefix + 1]
    ld, cache = model.decode(params, step, cache)
    return logits_full, lp, ld


@pytest.mark.parametrize("family", family_params())
def test_prefill_matches_forward(family, key):
    cfg = FAMILY_CONFIGS[family]
    logits_full, lp, _ = _full_and_incremental(cfg, key)
    np.testing.assert_allclose(np.asarray(lp),
                               np.asarray(logits_full[:, :16]),
                               rtol=TOL, atol=TOL)


@pytest.mark.parametrize("family", family_params())
def test_decode_matches_forward(family, key):
    cfg = FAMILY_CONFIGS[family]
    logits_full, _, ld = _full_and_incremental(cfg, key)
    np.testing.assert_allclose(np.asarray(ld[:, 0]),
                               np.asarray(logits_full[:, 16]),
                               rtol=TOL, atol=TOL)


def test_windowed_decode_matches_forward(key):
    cfg = dataclasses.replace(FAMILY_CONFIGS["dense"], sliding_window=8)
    logits_full, lp, ld = _full_and_incremental(cfg, key)
    np.testing.assert_allclose(np.asarray(ld[:, 0]),
                               np.asarray(logits_full[:, 16]),
                               rtol=TOL, atol=TOL)


@pytest.mark.slow
def test_windowed_long_decode_ring_buffer(key):
    """Decode many tokens past the window; compare against full forward."""
    cfg = dataclasses.replace(FAMILY_CONFIGS["dense"], sliding_window=8)
    model = build_model(cfg)
    params = model.init(key)
    T = 24
    toks = jax.random.randint(key, (1, T), 0, cfg.vocab_size)
    logits_full, _ = model.apply(params, {"tokens": toks, "labels": toks})

    cache = model.init_cache(params, 1, T)
    lp, cache = model.prefill(params, {"tokens": toks[:, :8]}, cache)
    outs = []
    for t in range(8, T):
        ld, cache = model.decode(params, {"tokens": toks[:, t:t + 1]}, cache)
        outs.append(np.asarray(ld[:, 0]))
    for i, t in enumerate(range(8, T)):
        np.testing.assert_allclose(outs[i], np.asarray(logits_full[:, t]),
                                   rtol=TOL, atol=TOL, err_msg=f"pos {t}")


@pytest.mark.slow
def test_decode_loop_greedy_consistency(key):
    """Greedy decode loop runs and produces valid token ids (all families)."""
    from repro.launch.steps import make_decode_step
    for family in sorted(FAMILY_CONFIGS):
        cfg = FAMILY_CONFIGS[family]
        model = build_model(cfg)
        params = model.init(key)
        pre = make_batch(cfg, key, batch=2, seq=8)
        cache = model.init_cache(params, 2, 32)
        _, cache = model.prefill(params, pre, cache)
        decode = jax.jit(make_decode_step(cfg))
        tok = pre["tokens"][..., -1:]
        for _ in range(3):
            tok, cache = decode(params, {"tokens": tok}, cache)
            assert (np.asarray(tok) >= 0).all()
            assert (np.asarray(tok) < cfg.vocab_size).all()
