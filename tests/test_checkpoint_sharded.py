"""Checkpoint round-trip of PLANNER-SHARDED state: save under a
``replica x data x model`` mesh, restore onto a DIFFERENT mesh shape,
and assert the deployable model survives exactly.

Runs in a subprocess (8 forced host devices, same rationale as
test_distributed_sync.py).  The flat-npz checkpoint format stores
host-gathered global arrays, so resharding is entirely a placement
concern: restore into the state template, then device_put onto the new
mesh's planner shardings.
"""
import os
import subprocess
import sys
import textwrap


_CHILD = textwrap.dedent("""
    import tempfile
    import jax, jax.numpy as jnp, numpy as np
    assert len(jax.devices()) == 8, jax.devices()
    from jax.sharding import PartitionSpec as P
    from repro.checkpoint import checkpoint as ckpt
    from repro.configs.base import ModelConfig, ParleConfig
    from repro.core import registry
    from repro.launch.mesh import make_mesh_from_spec, replica_axis_of
    from repro.models.model import build_model
    from repro.sharding import partition
    from repro.data.synthetic import TokenStream, replica_batches

    mcfg = ModelConfig(name="t-dense", family="dense", num_layers=2,
                       d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
                       vocab_size=512, head_dim=32)
    model = build_model(mcfg)
    algo = registry.get("parle")
    cfg = algo.canonicalize_cfg(ParleConfig(
        n_replicas=2, L=2, lr=0.1, lr_inner=0.1, batches_per_epoch=5))
    params = model.init(jax.random.PRNGKey(0))
    stream = TokenStream(vocab_size=mcfg.vocab_size, seq_len=16,
                         batch_size=2, seed=0)

    # ---- train a few steps under the composed mesh, then save ----
    mesh_a = make_mesh_from_spec("replica:2,data:2,model:2")
    raxis = replica_axis_of(mesh_a)
    specs_a = algo.state_pspecs(raxis, params=params, mesh=mesh_a)
    state = jax.device_put(algo.init(params, cfg),
                           partition.shardings(mesh_a, specs_a))
    step_a = algo.make_sharded_step(model.loss, cfg, mesh_a,
                                    replica_axis=raxis)
    for i in range(3):                   # crosses the L=2 sync boundary
        state, _ = step_a(state, replica_batches(stream, i, 2, 2))

    path = tempfile.mkdtemp() + "/sharded.npz"
    ckpt.save(path, state, step=3, meta={"arch": mcfg.name}, algo="parle")
    dep_before = jax.tree.map(np.asarray, algo.deployable(state))

    # ---- restore onto a DIFFERENT mesh shape (4-way FSDP, no TP) ----
    mesh_b = make_mesh_from_spec("replica:2,data:4")
    # the checkpoint carries n=2 replicas; restore into an n=2 template
    template2 = algo.init(jax.tree.map(jnp.zeros_like, params), cfg)
    restored = ckpt.restore(path, template2, algo="parle")
    specs_b = algo.state_pspecs("replica", params=params, mesh=mesh_b)
    restored = jax.device_put(restored,
                              partition.shardings(mesh_b, specs_b))
    wq = restored.x["blocks"]["attn"]["wq"]
    assert wq.sharding.spec == P("replica", None, "data", None), \\
        wq.sharding.spec

    # tree equality through Algorithm.deployable, exact
    dep_after = jax.tree.map(np.asarray, algo.deployable(restored))
    for a, b in zip(jax.tree.leaves(dep_before),
                    jax.tree.leaves(dep_after)):
        np.testing.assert_array_equal(a, b)

    # and it keeps TRAINING on the new mesh (placement is not cosmetic)
    step_b = algo.make_sharded_step(model.loss, cfg, mesh_b,
                                    replica_axis="replica")
    restored, m = step_b(restored, replica_batches(stream, 3, 2, 2))
    assert np.isfinite(float(m["loss"]))

    # mismatched algo stamp still refuses
    try:
        ckpt.restore(path, template2, algo="elastic_sgd")
        raise SystemExit("expected ValueError")
    except ValueError:
        pass
    print("SHARDED_CKPT_OK")
""")


def test_sharded_checkpoint_round_trip_across_meshes():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    res = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "SHARDED_CKPT_OK" in res.stdout, res.stdout
