"""The serving subsystem: first-token/off-by-one regression, the
continuous-batching engine's exact-match contract against the naive
loop, scheduler slot reuse under staggered arrivals, and sampling.

Tier-1 runs the dense-family paths; the other families ride the slow
lane (and the CI serving lane, which runs this file with the tier-1
filter overridden).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import FAMILY_CONFIGS, family_params
from repro.models.model import build_model, cache_positions
from repro.serving import (Engine, Request, SamplingParams, Scheduler,
                           make_naive_fns, naive_generate)

GEN = 8
MAX_LEN = 32
MIXED_LENS = (5, 9, 12, 7)

_PARAMS = {}


def _params(cfg, key):
    if cfg.name not in _PARAMS:
        _PARAMS[cfg.name] = build_model(cfg).init(key)
    return _PARAMS[cfg.name]


def _request(cfg, key, i, T):
    kk = jax.random.fold_in(key, 1000 + i)
    shape = (cfg.num_codebooks, T) if cfg.family == "audio" else (T,)
    req = {"tokens": np.asarray(
        jax.random.randint(kk, shape, 0, cfg.vocab_size), np.int32)}
    if cfg.family == "vlm":
        req["patch_embeds"] = jax.random.normal(
            jax.random.fold_in(kk, 1), (cfg.num_patches, cfg.d_model))
    if cfg.family == "audio":
        req["cond"] = jax.random.normal(
            jax.random.fold_in(kk, 2), (cfg.cond_len, cfg.d_model))
    return req


def _naive_reference(cfg, params, reqs, gen=GEN):
    fns = make_naive_fns(cfg)
    model = build_model(cfg)
    outs = []
    for r in reqs:
        batch = {k: jnp.asarray(v)[None] for k, v in r.items()}
        cache = model.init_cache(params, 1, MAX_LEN)
        toks, _ = naive_generate(fns, params, batch, cache, gen)
        outs.append(np.asarray(toks[0]))
    return outs


# ------------------------------------------------------------------
# [bugfix] first token from prefill logits + exact cache positions
# ------------------------------------------------------------------

def test_first_token_is_prefill_argmax(key):
    """The first emitted token must be argmax over the PREFILL logits'
    last position — not the last prompt token re-fed through decode."""
    cfg = FAMILY_CONFIGS["dense"]
    model = build_model(cfg)
    params = _params(cfg, key)
    T = 12
    batch = {"tokens": jax.random.randint(key, (2, T), 0, cfg.vocab_size)}
    logits, _ = model.prefill(params, batch,
                              model.init_cache(params, 2, MAX_LEN))
    expected_first = np.asarray(jnp.argmax(logits[:, -1], axis=-1))

    fns = make_naive_fns(cfg)
    toks, cache = naive_generate(fns, params, batch,
                                 model.init_cache(params, 2, MAX_LEN), GEN)
    np.testing.assert_array_equal(np.asarray(toks)[:, 0], expected_first)
    # G emitted tokens = prefill + (G-1) decodes: no double-fed prompt token
    assert int(np.asarray(cache_positions(cache))[()]) == T + GEN - 1


def test_cache_positions_advance_exactly(key):
    """prefill(T) + G decode steps -> cache position T + G exactly (the
    old loop wrote the last prompt token twice)."""
    cfg = FAMILY_CONFIGS["dense"]
    model = build_model(cfg)
    params = _params(cfg, key)
    T = 10
    batch = {"tokens": jax.random.randint(key, (2, T), 0, cfg.vocab_size)}
    cache = model.init_cache(params, 2, MAX_LEN)
    logits, cache = model.prefill(params, batch, cache)
    assert int(np.asarray(cache_positions(cache))[()]) == T
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    for g in range(1, GEN + 1):
        logits, cache = model.decode(params, {"tokens": tok}, cache)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        assert int(np.asarray(cache_positions(cache))[()]) == T + g


# ------------------------------------------------------------------
# [test] engine vs naive: bit-identical greedy tokens, mixed lengths
# ------------------------------------------------------------------

@pytest.mark.parametrize("family", family_params())
def test_engine_matches_naive_exactly(family, key):
    cfg = FAMILY_CONFIGS[family]
    params = _params(cfg, key)
    reqs = [_request(cfg, key, i, T) for i, T in enumerate(MIXED_LENS)]
    naive = _naive_reference(cfg, params, reqs)

    # fewer slots than requests: slots are reused as sequences finish
    eng = Engine(cfg, params, num_slots=2, max_len=MAX_LEN, decode_chunk=3)
    for r in reqs:
        eng.submit(r["tokens"], max_new_tokens=GEN, cond=r.get("cond"),
                   patch_embeds=r.get("patch_embeds"))
    res = eng.run()
    for i in range(len(reqs)):
        np.testing.assert_array_equal(res[i], naive[i], err_msg=f"req {i}")


def test_engine_eos_truncation_matches_naive(key):
    """EOS termination: the engine's output equals the naive sequence
    cut at the first EOS (speculative post-EOS chunk tokens dropped)."""
    cfg = FAMILY_CONFIGS["dense"]
    params = _params(cfg, key)
    reqs = [_request(cfg, key, i, T) for i, T in enumerate(MIXED_LENS)]
    naive = _naive_reference(cfg, params, reqs)
    # pick an EOS id that actually occurs mid-sequence in request 0
    eos = int(naive[0][GEN // 2])

    def truncate(seq):
        hits = np.flatnonzero(seq == eos)
        return seq[:hits[0] + 1] if hits.size else seq

    eng = Engine(cfg, params, num_slots=2, max_len=MAX_LEN, decode_chunk=3)
    for r in reqs:
        eng.submit(r["tokens"], max_new_tokens=GEN, eos_id=eos)
    res = eng.run()
    for i in range(len(reqs)):
        np.testing.assert_array_equal(res[i], truncate(naive[i]),
                                      err_msg=f"req {i}")


def test_engine_staggered_arrivals_match(key):
    """Requests arriving over time (continuous batching, not one static
    batch) still produce the exact naive tokens."""
    cfg = FAMILY_CONFIGS["dense"]
    params = _params(cfg, key)
    reqs = [_request(cfg, key, i, T) for i, T in enumerate(MIXED_LENS)]
    naive = _naive_reference(cfg, params, reqs)

    eng = Engine(cfg, params, num_slots=2, max_len=MAX_LEN, decode_chunk=3)
    for i, r in enumerate(reqs):
        eng.submit(r["tokens"], max_new_tokens=GEN, arrival=i)
    res = eng.run()
    for i in range(len(reqs)):
        np.testing.assert_array_equal(res[i], naive[i], err_msg=f"req {i}")


# ------------------------------------------------------------------
# [test] scheduler unit: staggered arrivals, slot reuse after EOS
# ------------------------------------------------------------------

def test_scheduler_slot_reuse_after_eos():
    s = Scheduler(2)
    s.submit(Request(uid=0, tokens=np.arange(4), max_new_tokens=3))
    s.submit(Request(uid=1, tokens=np.arange(5), max_new_tokens=8, eos_id=7))
    s.submit(Request(uid=2, tokens=np.arange(3), max_new_tokens=2, arrival=2))

    pairs = s.admissible()
    assert [(i, r.uid) for i, r in pairs] == [(0, 0), (1, 1)]
    assert not s.place(0, pairs[0][1], 10)
    assert not s.place(1, pairs[1][1], 11)
    assert s.admissible() == []          # uid 2 hasn't arrived yet

    # chunk of 3 steps: uid0 hits max_new at step 2, uid1 hits EOS (7)
    freed = s.absorb_chunk(np.array([[1, 2], [2, 7], [3, 8]]))
    assert sorted(freed) == [0, 1]
    assert s.active_slots() == []
    assert s.finished[0].tokens().tolist() == [10, 1, 2]     # max-len stop
    assert s.finished[1].tokens().tolist() == [11, 2, 7]     # EOS stop

    # uid 2 arrives at step 2: not admissible at step 1, then reuses slot 0
    assert s.step_count == 1 and s.admissible() == []
    s.absorb_chunk(np.zeros((1, 2), np.int32))               # idle tick
    pairs = s.admissible()
    assert [(i, r.uid) for i, r in pairs] == [(0, 2)]
    assert not s.place(0, pairs[0][1], 20)
    s.absorb_chunk(np.array([[21, 0]]))
    assert s.finished[2].tokens().tolist() == [20, 21]
    assert not s.has_work()


def test_scheduler_single_token_budget():
    """max_new_tokens=1 finishes at placement — the slot frees instantly."""
    s = Scheduler(1)
    s.submit(Request(uid=0, tokens=np.arange(4), max_new_tokens=1))
    (slot, req), = s.admissible()
    assert s.place(slot, req, 5)
    assert s.free_slots() == [0]
    assert s.finished[0].tokens().tolist() == [5]


# ------------------------------------------------------------------
# sampling
# ------------------------------------------------------------------

def test_sampling_topk1_equals_greedy(key):
    """top_k=1 with any temperature collapses to the greedy argmax."""
    cfg = FAMILY_CONFIGS["dense"]
    params = _params(cfg, key)
    reqs = [_request(cfg, key, i, T) for i, T in enumerate(MIXED_LENS[:2])]
    naive = _naive_reference(cfg, params, reqs)
    eng = Engine(cfg, params, num_slots=2, max_len=MAX_LEN, decode_chunk=3,
                 sampling=SamplingParams(temperature=0.8, top_k=1))
    for r in reqs:
        eng.submit(r["tokens"], max_new_tokens=GEN)
    res = eng.run()
    for i in range(len(reqs)):
        np.testing.assert_array_equal(res[i], naive[i])


def test_sampling_temperature_valid_tokens(key):
    cfg = FAMILY_CONFIGS["dense"]
    params = _params(cfg, key)
    reqs = [_request(cfg, key, i, T) for i, T in enumerate(MIXED_LENS[:2])]
    eng = Engine(cfg, params, num_slots=2, max_len=MAX_LEN, decode_chunk=3,
                 sampling=SamplingParams(temperature=1.0, top_k=8), seed=3)
    for r in reqs:
        eng.submit(r["tokens"], max_new_tokens=GEN)
    res = eng.run()
    for i in range(len(reqs)):
        assert res[i].shape == (GEN,)
        assert (res[i] >= 0).all() and (res[i] < cfg.vocab_size).all()
