"""Execution runtime (repro/runtime/): sync-policy contract, async
consensus math, the host-side coordinator, and the elastic dist_run pod.

Tier-1: pure-math units (staleness weighting, contribution/apply round
trips, single-worker async == barrier equivalence), the in-process
coordinator protocol, checkpoint plumbing, and the pod-merge gap
accounting.  Slow lane: real multi-process pods — the orphan-kill path
and the 4 -> 2 / 4 -> 6 elastic resume continuity checks.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ParleConfig
from repro.core import parle, registry
from repro.runtime import (AsyncElasticPolicy, BarrierPolicy, Coordinator,
                           CoordinatorClient, OverlapPolicy, consensus_digest,
                           load_consensus, policy_for)
from repro.runtime.coordinator import _np_dequant


def _loss(p, b):
    return jnp.mean((p["w"] @ p["m"] - b["t"]) ** 2), ()


def _params(key):
    return {"w": jax.random.normal(key, (8, 16)) * 0.1,
            "m": jax.random.normal(jax.random.fold_in(key, 1), (16, 4)) * 0.1}


def _round_batches(key, L, n):
    return {"t": jax.random.normal(key, (L, n, 8, 4))}


def _cfg(n=2, L=3, sync_compress="none"):
    algo = registry.get("parle")
    return algo.canonicalize_cfg(ParleConfig(
        n_replicas=n, L=L, lr=0.05, lr_inner=0.05, batches_per_epoch=5,
        sync_compress=sync_compress))


# ------------------------------------------------------------------
# staleness-weighted mean (the async Eq. 8d reference)
# ------------------------------------------------------------------

def test_staleness_single_contribution_is_identity():
    means = [np.arange(6, dtype=np.float32)]
    out = parle.staleness_weighted_mean(means, [3], [7])
    assert out is means[0]          # no float round-trip on n=1


def test_staleness_equal_rounds_is_count_weighted_mean():
    a = [np.ones(4, np.float32) * 2.0]
    b = [np.ones(4, np.float32) * 8.0]
    out = parle.staleness_weighted_mean([a, b], [3, 1], [5, 5])
    np.testing.assert_allclose(out[0], (3 * 2.0 + 1 * 8.0) / 4, rtol=1e-6)


def test_staleness_decay_downweights_lagging_worker():
    fresh = [np.zeros(4, np.float32)]
    stale = [np.ones(4, np.float32)]
    out = parle.staleness_weighted_mean([fresh, stale], [1, 1], [10, 8],
                                        decay=0.5)
    # w_stale = 0.25 -> consensus = 0.25 / 1.25 = 0.2
    np.testing.assert_allclose(out[0], 0.2, rtol=1e-6)
    # decay=1.0: staleness ignored, plain mean
    out = parle.staleness_weighted_mean([fresh, stale], [1, 1], [10, 8],
                                        decay=1.0)
    np.testing.assert_allclose(out[0], 0.5, rtol=1e-6)


def test_staleness_zero_contributions_raises():
    with pytest.raises(ValueError):
        parle.staleness_weighted_mean([], [], [])


# ------------------------------------------------------------------
# contribution -> dequant -> consensus round trip
# ------------------------------------------------------------------

@pytest.mark.parametrize("method", ["none", "bf16", "int8"])
def test_async_contribution_round_trip(method):
    cfg = _cfg(sync_compress=method)
    algo = registry.get("parle")
    state = algo.init(_params(jax.random.PRNGKey(0)), cfg)
    payload, e_new = parle.async_contribution(state, cfg)
    flat, _ = jax.tree_util.tree_flatten(state.x)
    assert len(payload) == len(flat)
    means = [_np_dequant(p["q"], p["scales"], method).mean(axis=0)
             for p in payload]
    xbar = parle.consensus_from_flat(means, state.x)
    want = jax.tree.map(lambda l: np.asarray(jnp.mean(l, 0)), state.x)
    got = jax.tree.map(np.asarray, xbar)
    for k in want:
        assert got[k].shape == want[k].shape
        if method == "none":
            np.testing.assert_array_equal(got[k], want[k])
        else:
            np.testing.assert_allclose(got[k], want[k], atol=2e-2)
    if method == "none":
        assert e_new is None
    else:
        # residual tree mirrors x and carries the quantization error
        assert jax.tree_util.tree_structure(e_new) \
            == jax.tree_util.tree_structure(state.x)


def test_consensus_from_flat_trims_codec_padding():
    cfg = _cfg(sync_compress="int8")
    algo = registry.get("parle")
    state = algo.init(_params(jax.random.PRNGKey(1)), cfg)
    payload, _ = parle.async_contribution(state, cfg)
    flat, _ = jax.tree_util.tree_flatten(state.x)
    for p, l in zip(payload, flat):
        assert p["q"].shape[1] >= l[0].size       # padded to codec chunk
    means = [_np_dequant(p["q"], p["scales"], "int8").mean(axis=0)
             for p in payload]
    xbar = parle.consensus_from_flat(means, state.x)
    for leaf, like in zip(jax.tree_util.tree_leaves(xbar), flat):
        assert leaf.shape == like.shape[1:]


# ------------------------------------------------------------------
# single-worker async == barrier (the n-of-1 equivalence anchor)
# ------------------------------------------------------------------

def test_single_worker_async_matches_barrier_bitwise():
    cfg = _cfg(n=2, L=3)
    algo = registry.get("parle")
    params = _params(jax.random.PRNGKey(0))
    barrier_round = algo.make_round_fn(_loss, cfg)
    inner_round = parle.make_inner_round_fn(_loss, cfg)
    apply_fn = parle.make_async_apply_fn(cfg)

    s_bar = parle.dealias_state(algo.init(params, cfg))
    s_async = parle.dealias_state(algo.init(params, cfg))
    for r in range(2):
        rb = _round_batches(jax.random.PRNGKey(20 + r), cfg.L,
                            cfg.n_replicas)
        s_bar, m_bar = barrier_round(s_bar, rb)
        s_async, m_async = inner_round(s_async, rb)
        # the coordinator path with ONE worker: consensus == own mean
        payload, e_new = parle.async_contribution(s_async, cfg)
        means = parle.staleness_weighted_mean(
            [[_np_dequant(p["q"], p["scales"], "none").mean(axis=0)
              for p in payload]], [cfg.n_replicas], [r])
        s_async = apply_fn(s_async,
                           parle.consensus_from_flat(means, s_async.x))
        np.testing.assert_allclose(float(m_bar["loss"]),
                                   float(m_async["loss"]), rtol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(
            jax.tree.map(np.asarray, s_bar)),
            jax.tree_util.tree_leaves(jax.tree.map(np.asarray, s_async))):
        np.testing.assert_array_equal(a, b)


# ------------------------------------------------------------------
# policy contract
# ------------------------------------------------------------------

def test_policy_for_resolution():
    assert isinstance(policy_for(_cfg()), BarrierPolicy)
    assert isinstance(policy_for(None, "overlap"), OverlapPolicy)
    import dataclasses
    ov = dataclasses.replace(_cfg(), sync_overlap=True)
    assert isinstance(policy_for(ov), OverlapPolicy)
    with pytest.raises(ValueError):
        policy_for(None, "async")


def test_async_policy_rejects_step_and_mesh_programs():
    pol = AsyncElasticPolicy(client=None, pcfg=_cfg(), obs=None, worker=0)
    with pytest.raises(SystemExit):
        pol.make_step_fn(registry.get("parle"), _loss, _cfg())
    with pytest.raises(SystemExit):
        pol.make_round_fn(registry.get("parle"), _loss, _cfg(),
                          mesh=object())
    assert pol.make_flush_fn(registry.get("parle"), _cfg()) is None


# ------------------------------------------------------------------
# coordinator protocol (in-process, real sockets)
# ------------------------------------------------------------------

def _vec_payload(value, size=8):
    return [{"q": np.full((1, size), value, np.float32), "scales": None}]


def test_coordinator_join_exchange_leave_elastic(tmp_path):
    from repro.obs import EventSink, read_events
    sink = EventSink(str(tmp_path / "coord.jsonl"))
    coord = Coordinator(0, method="none", decay=0.5, sink=sink)
    port = coord._listener.address[1]
    try:
        c0 = CoordinatorClient(port, "worker0", count=1)
        c1 = CoordinatorClient(port, "worker1", count=1)
        hello = c0.join()
        assert hello["consensus"] is None and hello["round"] == 0
        assert c1.join()["n_active"] == 2

        r = c0.exchange(_vec_payload(2.0), round_idx=1)
        np.testing.assert_allclose(r["consensus"][0], 2.0)
        assert r["staleness"] == 0
        r = c1.exchange(_vec_payload(6.0), round_idx=1)
        np.testing.assert_allclose(r["consensus"][0], 4.0)   # same round

        # worker1 leaves: its contribution leaves the table, consensus
        # rebalances over the survivor
        c1.leave()
        r = c0.exchange(_vec_payload(3.0), round_idx=2)
        np.testing.assert_allclose(r["consensus"][0], 3.0)
        assert r["n_active"] == 1
        c0.leave()
    finally:
        coord.close()
        sink.close()
    kinds = [e["kind"] for e in read_events(str(tmp_path / "coord.jsonl"))]
    assert kinds.count("worker_join") == 2
    assert kinds.count("worker_leave") == 2


def test_coordinator_dead_connection_is_implicit_leave():
    coord = Coordinator(0, method="none")
    port = coord._listener.address[1]
    try:
        c0 = CoordinatorClient(port, "worker0")
        c1 = CoordinatorClient(port, "worker1")
        c0.join()
        c1.join()
        c1.exchange(_vec_payload(10.0), round_idx=1)
        c1.conn.close()                   # crash, not a polite leave
        import time
        deadline = time.monotonic() + 5
        while "worker1" in coord._active and time.monotonic() < deadline:
            time.sleep(0.01)
        assert "worker1" not in coord._active
        r = c0.exchange(_vec_payload(2.0), round_idx=1)
        np.testing.assert_allclose(r["consensus"][0], 2.0)
        c0.leave()
    finally:
        coord.close()


def test_coordinator_checkpoint_round_trip(tmp_path):
    path = str(tmp_path / "consensus.npz")
    coord = Coordinator(0, method="none", decay=0.25)
    port = coord._listener.address[1]
    try:
        with pytest.raises(ValueError):
            coord.save(path)              # nothing exchanged yet
        c = CoordinatorClient(port, "worker0", count=2)
        c.join()
        c.exchange(_vec_payload(5.0), round_idx=3)
        coord.save(path, metrics=[{"name": "pod.steps", "labels": {},
                                   "total": 9}])
        digest = coord.digest()
        c.leave()
    finally:
        coord.close()
    vectors, rnd, meta = load_consensus(path)
    assert rnd == 3
    assert consensus_digest(vectors) == digest == meta["digest"]
    assert meta["kind"] == "async_consensus" and meta["decay"] == 0.25
    assert meta["workers"]["worker0"] == {"round": 3, "count": 2}
    np.testing.assert_allclose(vectors[0], 5.0)
    from repro.checkpoint import checkpoint as ckpt
    assert ckpt.saved_metrics(path)[0]["total"] == 9
    flat = ckpt.load_flat(path)
    assert list(flat) == ["consensus/0"]


# ------------------------------------------------------------------
# pod-merge gap accounting (satellite: missing worker files)
# ------------------------------------------------------------------

def test_merge_pod_obs_counts_missing_workers(tmp_path):
    from repro.launch.dist_run import _merge_pod_obs, build_argparser
    from repro.obs import EventSink, Registry, read_events
    mpath = str(tmp_path / "pod.jsonl")
    args = build_argparser().parse_args(
        ["--nproc", "3", "--metrics-out", mpath])
    # worker 0: full snapshot; worker 1: file exists but crashed before
    # the final snapshot; worker 2: no file at all
    reg = Registry()
    reg.counter("pod.steps").inc(4)
    s = EventSink(f"{mpath}.worker0")
    s.emit("metrics_snapshot", snapshot=reg.snapshot())
    s.close()
    s = EventSink(f"{mpath}.worker1")
    s.emit("note", msg="crashed before finalize")
    s.close()
    merged = _merge_pod_obs(args)
    assert merged["counters"][0]["total"] == 4
    evs = read_events(mpath)
    assert [e["kind"] for e in evs] == ["note", "note", "pod_merged"]
    assert evs[-1]["processes"] == 1
    assert evs[-1]["missing_workers"] == 2
    assert "worker 1" in evs[0]["msg"] and "worker 2" in evs[1]["msg"]


# ------------------------------------------------------------------
# slow lane: real pods
# ------------------------------------------------------------------

def _pod_env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    return env


def _run_pod(extra, env=None, timeout=1200):
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dist_run", "--algo", "parle",
         "--smoke", "--steps", "6", "--L", "3"] + extra,
        env=env or _pod_env(), capture_output=True, text=True,
        timeout=timeout)


@pytest.mark.slow
def test_failed_worker_kills_orphaned_peers():
    env = _pod_env()
    env["REPRO_TEST_FAIL_WORKER"] = "1"
    res = _run_pod(["--nproc", "2", "--mesh", "pod:2", "--port", "9411"],
                   env=env)
    assert res.returncode == 41, res.stdout + res.stderr
    assert "worker 1 exited rc=41" in res.stderr
    assert "killed 1 orphaned peer" in res.stderr
    assert "injected test failure" in res.stderr   # failing worker's tail


@pytest.mark.slow
def test_async_elastic_resume_grow_and_shrink(tmp_path):
    """Satellite: checkpoint a 4-worker async pod, resume as 2- and
    6-worker pods; consensus continuity (digest) + monotonic counters."""
    ck = str(tmp_path / "async_ck.npz")

    def pod(nproc, port, tag, resume=False):
        mpath = str(tmp_path / f"pod_{tag}.jsonl")
        extra = ["--nproc", str(nproc), "--sync-policy", "async",
                 "--replicas", "12", "--port", str(port),
                 "--metrics-out", mpath]
        extra += (["--resume", ck] if resume else ["--checkpoint-out", ck])
        res = _run_pod(extra)
        assert res.returncode == 0, res.stdout + res.stderr
        out = {}
        for line in res.stdout.splitlines():
            if line.startswith("{"):
                out.update(json.loads(line))
        from repro.obs import read_events
        merged = [e for e in read_events(mpath)
                  if e["kind"] == "pod_merged"][-1]
        out["counters"] = {c["name"]: c["total"]
                           for c in merged["snapshot"]["counters"]}
        assert merged["missing_workers"] == 0
        return out

    a = pod(4, 9421, "a")
    assert a["counters"]["pod.steps"] == 4 * 6
    assert a["async_checkpoint"] == ck and a["round"] == 2
    digest = a["consensus_digest"]
    vectors, rnd, meta = load_consensus(ck)
    assert rnd == 2 and consensus_digest(vectors) == digest
    ck_l2 = float(np.sqrt(sum(
        float(np.sum(np.square(np.asarray(v, np.float64))))
        for v in vectors)))

    for nproc, port, tag in ((2, 9431, "b"), (6, 9441, "c")):
        r = pod(nproc, port, tag, resume=True)
        # continuity: the resumed pod starts FROM the checkpointed
        # consensus — every replica is initialized at it and x only
        # moves at consensus applies, so the first exchanged consensus
        # IS the checkpoint's (up to arrival-order fold rounding, hence
        # the norm comparison rather than the bitwise digest)
        assert r["consensus_digest"] == digest          # async_resume echo
        np.testing.assert_allclose(r["first_consensus_l2"], ck_l2,
                                   rtol=1e-5)
        assert r["base_round"] == 2
        # monotonic counters: the checkpoint's stamp folds into the
        # resumed pod's merged snapshot
        assert r["counters"]["pod.steps"] == 4 * 6 + nproc * 6
        assert r["counters"]["pod.rounds"] == 4 * 2 + nproc * 2
