"""Per-kernel allclose sweeps against the pure-jnp oracles in
kernels/ref.py (interpret mode executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


# ------------------------------------------------------------------
# parle_update
# ------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(8, 1024), (3, 1000), (17,), (2, 5, 129)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_parle_update_shapes(shape, dtype, key):
    ks = jax.random.split(key, 5)
    y, z, v, g, x = [jax.random.normal(k, shape, dtype) for k in ks]
    kw = dict(inv_gamma=0.01, lr=0.1, mu=0.9, alpha=0.75)
    ko = ops.parle_inner_update({"w": y}, {"w": z}, {"w": v}, {"w": g},
                                {"w": x}, **kw)
    ro = ref.parle_inner_update(y, z, v, g, x, **kw)
    for a, b in zip(ko, ro):
        np.testing.assert_allclose(np.asarray(a["w"]), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


def test_parle_update_multi_leaf_tree(key):
    tree = {"a": jax.random.normal(key, (4, 7)),
            "b": {"c": jax.random.normal(key, (33,))}}
    zeros = jax.tree.map(jnp.zeros_like, tree)
    kw = dict(inv_gamma=0.1, lr=0.05, mu=0.9, alpha=0.5)
    y2, z2, v2 = ops.parle_inner_update(tree, zeros, zeros, tree, zeros, **kw)
    ry, rz, rv = ref.parle_inner_update(tree["a"], zeros["a"], zeros["a"],
                                        tree["a"], zeros["a"], **kw)
    np.testing.assert_allclose(np.asarray(y2["a"]), np.asarray(ry), rtol=1e-6)


# ------------------------------------------------------------------
# flash_attention
# ------------------------------------------------------------------

# tier-1 keeps one block-shape combo per head dim; the full sweep
# rides the slow lane (CI kernel job runs with addopts overridden)
@pytest.mark.parametrize("T,bq,bk", [
    (128, 128, 64),
    pytest.param(128, 64, 64, marks=pytest.mark.slow),
    pytest.param(256, 128, 128, marks=pytest.mark.slow),
    pytest.param(64, 64, 64, marks=pytest.mark.slow),
])
@pytest.mark.parametrize("hd", [32, 64])
def test_flash_attention_causal(T, bq, bk, hd, key):
    B, H = 2, 3
    ks = jax.random.split(key, 3)
    q, k, v = [jax.random.normal(kk, (B, T, H, hd)) for kk in ks]
    o_k = ops.flash_attention(q, k, v, block_q=bq, block_k=bk)
    o_r = ref.flash_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [
    32,
    pytest.param(16, marks=pytest.mark.slow),
    pytest.param(100, marks=pytest.mark.slow),
])
def test_flash_attention_window(window, key):
    B, T, H, hd = 1, 128, 2, 32
    ks = jax.random.split(key, 3)
    q, k, v = [jax.random.normal(kk, (B, T, H, hd)) for kk in ks]
    o_k = ops.flash_attention(q, k, v, window=window, block_q=64, block_k=64)
    o_r = ref.flash_attention(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype, key):
    B, T, H, hd = 1, 128, 2, 64
    ks = jax.random.split(key, 3)
    q, k, v = [jax.random.normal(kk, (B, T, H, hd)).astype(dtype) for kk in ks]
    o_k = ops.flash_attention(q, k, v, block_q=64, block_k=64)
    o_r = ref.flash_attention(q, k, v)
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_r, np.float32), rtol=tol, atol=tol)


# ------------------------------------------------------------------
# ssd_scan
# ------------------------------------------------------------------

@pytest.mark.parametrize("T,chunk", [
    (128, 128),
    pytest.param(64, 16, marks=pytest.mark.slow),
    pytest.param(128, 32, marks=pytest.mark.slow),
    pytest.param(96, 32, marks=pytest.mark.slow),
])
@pytest.mark.parametrize("N,P", [(16, 32), (64, 64)])
def test_ssd_scan_vs_naive(T, chunk, N, P, key):
    B, nh = 2, 3
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, T, nh, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, T, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, T, N)) * 0.5
    yk, hk = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    yr, hr = ref.ssd_scan(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(hr),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.slow
def test_ssd_chunked_jnp_path_vs_naive(key):
    """The model's pure-jnp chunked path against the naive recurrence,
    including a resume-from-state (h0) case the kernel delegates."""
    from repro.models.mamba2 import ssd_chunked
    B, T, nh, P, N = 1, 64, 2, 16, 8
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (B, T, nh, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, T, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, T, N)) * 0.5
    h0 = jax.random.normal(ks[5], (B, nh, N, P)) * 0.1
    yk, hk = ssd_chunked(x, dt, A, Bm, Cm, 16, h0=h0)
    yr, hr = ref.ssd_scan(x, dt, A, Bm, Cm, h0=h0)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(hr),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------
# paged_attention
# ------------------------------------------------------------------

@pytest.mark.parametrize("H,KV,hd", [
    (4, 2, 32),
    pytest.param(4, 4, 64, marks=pytest.mark.slow),     # MHA, no GQA fold
    pytest.param(8, 2, 16, marks=pytest.mark.slow),     # wide GQA group
])
@pytest.mark.parametrize("ps,M", [
    (16, 4),
    pytest.param(8, 7, marks=pytest.mark.slow),         # odd page count
])
def test_paged_attention_vs_oracle(H, KV, hd, ps, M, key):
    """The Pallas paged-decode kernel against the gather-then-softmax
    oracle: random page tables (rows share pages, trash page unused
    entries) and ragged per-row lengths."""
    B, P = 3, 12
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, H, hd))
    k_pool = jax.random.normal(ks[1], (P, ps, KV, hd))
    v_pool = jax.random.normal(ks[2], (P, ps, KV, hd))
    # each row gets a random permutation of usable pages; entries past
    # the row's live extent point at the trash page (id 0)
    rng = np.random.default_rng(0)
    table = np.stack([rng.permutation(np.arange(1, P))[:M] for _ in range(B)])
    lengths = np.array([1, ps * M, ps * (M - 1) + ps // 2], np.int32)[:B]
    for b in range(B):
        used = -(-int(lengths[b]) // ps)
        table[b, used:] = 0
    o_k = ops.paged_attention(q, k_pool, v_pool, jnp.asarray(table, jnp.int32),
                              jnp.asarray(lengths))
    o_r = ref.paged_attention(q, k_pool, v_pool, jnp.asarray(table, jnp.int32),
                              jnp.asarray(lengths))
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.slow
def test_paged_attention_shared_pages(key):
    """Two rows whose tables name the SAME pages (prefix sharing) score
    identically up to their common live extent."""
    H, KV, hd, ps, M, P = 4, 2, 32, 8, 3, 8
    ks = jax.random.split(key, 3)
    q1 = jax.random.normal(ks[0], (1, H, hd))
    q = jnp.concatenate([q1, q1], axis=0)
    k_pool = jax.random.normal(ks[1], (P, ps, KV, hd))
    v_pool = jax.random.normal(ks[2], (P, ps, KV, hd))
    table = jnp.asarray([[3, 5, 1], [3, 5, 2]], jnp.int32)  # shared prefix
    lengths = jnp.asarray([2 * ps, 2 * ps], jnp.int32)      # live < page 3
    o = ops.paged_attention(q, k_pool, v_pool, table, lengths)
    np.testing.assert_array_equal(np.asarray(o[0]), np.asarray(o[1]))
