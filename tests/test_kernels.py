"""Per-kernel allclose sweeps against the pure-jnp oracles in
kernels/ref.py (interpret mode executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


# ------------------------------------------------------------------
# parle_update
# ------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(8, 1024), (3, 1000), (17,), (2, 5, 129)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_parle_update_shapes(shape, dtype, key):
    ks = jax.random.split(key, 5)
    y, z, v, g, x = [jax.random.normal(k, shape, dtype) for k in ks]
    kw = dict(inv_gamma=0.01, lr=0.1, mu=0.9, alpha=0.75)
    ko = ops.parle_inner_update({"w": y}, {"w": z}, {"w": v}, {"w": g},
                                {"w": x}, **kw)
    ro = ref.parle_inner_update(y, z, v, g, x, **kw)
    for a, b in zip(ko, ro):
        np.testing.assert_allclose(np.asarray(a["w"]), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


def test_parle_update_multi_leaf_tree(key):
    tree = {"a": jax.random.normal(key, (4, 7)),
            "b": {"c": jax.random.normal(key, (33,))}}
    zeros = jax.tree.map(jnp.zeros_like, tree)
    kw = dict(inv_gamma=0.1, lr=0.05, mu=0.9, alpha=0.5)
    y2, z2, v2 = ops.parle_inner_update(tree, zeros, zeros, tree, zeros, **kw)
    ry, rz, rv = ref.parle_inner_update(tree["a"], zeros["a"], zeros["a"],
                                        tree["a"], zeros["a"], **kw)
    np.testing.assert_allclose(np.asarray(y2["a"]), np.asarray(ry), rtol=1e-6)


# ------------------------------------------------------------------
# flash_attention
# ------------------------------------------------------------------

# tier-1 keeps one block-shape combo per head dim; the full sweep
# rides the slow lane (CI kernel job runs with addopts overridden)
@pytest.mark.parametrize("T,bq,bk", [
    (128, 128, 64),
    pytest.param(128, 64, 64, marks=pytest.mark.slow),
    pytest.param(256, 128, 128, marks=pytest.mark.slow),
    pytest.param(64, 64, 64, marks=pytest.mark.slow),
])
@pytest.mark.parametrize("hd", [32, 64])
def test_flash_attention_causal(T, bq, bk, hd, key):
    B, H = 2, 3
    ks = jax.random.split(key, 3)
    q, k, v = [jax.random.normal(kk, (B, T, H, hd)) for kk in ks]
    o_k = ops.flash_attention(q, k, v, block_q=bq, block_k=bk)
    o_r = ref.flash_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [
    32,
    pytest.param(16, marks=pytest.mark.slow),
    pytest.param(100, marks=pytest.mark.slow),
])
def test_flash_attention_window(window, key):
    B, T, H, hd = 1, 128, 2, 32
    ks = jax.random.split(key, 3)
    q, k, v = [jax.random.normal(kk, (B, T, H, hd)) for kk in ks]
    o_k = ops.flash_attention(q, k, v, window=window, block_q=64, block_k=64)
    o_r = ref.flash_attention(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype, key):
    B, T, H, hd = 1, 128, 2, 64
    ks = jax.random.split(key, 3)
    q, k, v = [jax.random.normal(kk, (B, T, H, hd)).astype(dtype) for kk in ks]
    o_k = ops.flash_attention(q, k, v, block_q=64, block_k=64)
    o_r = ref.flash_attention(q, k, v)
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_r, np.float32), rtol=tol, atol=tol)


# ------------------------------------------------------------------
# ssd_scan
# ------------------------------------------------------------------

@pytest.mark.parametrize("T,chunk", [
    (128, 128),
    pytest.param(64, 16, marks=pytest.mark.slow),
    pytest.param(128, 32, marks=pytest.mark.slow),
    pytest.param(96, 32, marks=pytest.mark.slow),
])
@pytest.mark.parametrize("N,P", [(16, 32), (64, 64)])
def test_ssd_scan_vs_naive(T, chunk, N, P, key):
    B, nh = 2, 3
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, T, nh, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, T, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, T, N)) * 0.5
    yk, hk = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    yr, hr = ref.ssd_scan(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(hr),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.slow
def test_ssd_chunked_jnp_path_vs_naive(key):
    """The model's pure-jnp chunked path against the naive recurrence,
    including a resume-from-state (h0) case the kernel delegates."""
    from repro.models.mamba2 import ssd_chunked
    B, T, nh, P, N = 1, 64, 2, 16, 8
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (B, T, nh, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, T, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, T, N)) * 0.5
    h0 = jax.random.normal(ks[5], (B, nh, N, P)) * 0.1
    yk, hk = ssd_chunked(x, dt, A, Bm, Cm, 16, h0=h0)
    yr, hr = ref.ssd_scan(x, dt, A, Bm, Cm, h0=h0)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(hr),
                               rtol=1e-4, atol=1e-4)
