"""Shared fixtures.  NOTE: no XLA_FLAGS device-count override here —
smoke tests and benches must see the single real CPU device (the 512
placeholder devices belong ONLY to launch/dryrun.py)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ModelConfig


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running coverage, excluded from the tier-1 default "
        "run (pytest.ini addopts); select with -m slow")


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


FAMILY_CONFIGS = {
    "dense": ModelConfig(name="t-dense", family="dense", num_layers=2,
                         d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
                         vocab_size=512, head_dim=32),
    "moe": ModelConfig(name="t-moe", family="moe", num_layers=2, d_model=128,
                       num_heads=4, num_kv_heads=2, d_ff=0, vocab_size=512,
                       head_dim=32, num_experts=4, top_k=2, expert_d_ff=128,
                       num_shared_experts=1, shared_expert_d_ff=128,
                       # generous capacity: decode-vs-forward tests need
                       # drop-free routing (capacity drops are exercised
                       # separately in test_models)
                       capacity_factor=8.0),
    "ssm": ModelConfig(name="t-ssm", family="ssm", num_layers=2, d_model=128,
                       num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=512,
                       ssm_state=16, ssm_head_dim=32, ssm_chunk=16),
    "hybrid": ModelConfig(name="t-hybrid", family="hybrid", num_layers=3,
                          d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
                          vocab_size=512, head_dim=32, ssm_state=16,
                          ssm_head_dim=32, ssm_chunk=16, attn_every=2),
    "vlm": ModelConfig(name="t-vlm", family="vlm", num_layers=2, d_model=128,
                       num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=512,
                       head_dim=32, num_patches=8, qkv_bias=True),
    "audio": ModelConfig(name="t-audio", family="audio", num_layers=2,
                         d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
                         vocab_size=64, head_dim=32, num_codebooks=4,
                         cond_len=4),
}


# tier-1 family sweeps run "dense" only; the other five families ride
# the slow lane (-m slow).  Family coverage stays in tier-1 through the
# two TIER1_ARCHS end-to-end smokes (dense + ssm) — the per-family
# sweeps here cost 5-25 s of XLA compile each on this CPU container.
TIER1_FAMILIES = ("dense",)


def family_params():
    return [f if f in TIER1_FAMILIES else
            pytest.param(f, marks=pytest.mark.slow)
            for f in sorted(FAMILY_CONFIGS)]


def make_batch(cfg, key, batch=2, seq=32):
    kt, kp, kc = jax.random.split(key, 3)
    if cfg.family == "audio":
        toks = jax.random.randint(kt, (batch, cfg.num_codebooks, seq), 0,
                                  cfg.vocab_size)
        return {"tokens": toks, "labels": toks,
                "cond": jax.random.normal(kc, (batch, cfg.cond_len, cfg.d_model))}
    toks = jax.random.randint(kt, (batch, seq), 0, cfg.vocab_size)
    b = {"tokens": toks, "labels": toks}
    if cfg.family == "vlm":
        b["patch_embeds"] = jax.random.normal(
            kp, (batch, cfg.num_patches, cfg.d_model))
    return b
