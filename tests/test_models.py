"""Forward/loss/grad sanity for every model family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import FAMILY_CONFIGS, family_params, make_batch
from repro.models.model import build_model


@pytest.mark.parametrize("family", family_params())
def test_forward_shapes_and_finiteness(family, key):
    cfg = FAMILY_CONFIGS[family]
    model = build_model(cfg)
    params = model.init(key)
    batch = make_batch(cfg, key)
    logits, aux = model.apply(params, batch)
    if family == "audio":
        assert logits.shape == (2, 32, cfg.num_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (2, 32, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("family", family_params())
def test_loss_and_grads_finite(family, key):
    cfg = FAMILY_CONFIGS[family]
    model = build_model(cfg)
    params = model.init(key)
    batch = make_batch(cfg, key)
    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss))
    # loss near ln(V) at init
    assert float(metrics["ce"]) < np.log(cfg.vocab_size) * 1.5
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    for g in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(g)).all()


@pytest.mark.parametrize("family", family_params())
def test_loss_decreases_under_sgd(family, key):
    cfg = FAMILY_CONFIGS[family]
    model = build_model(cfg)
    params = model.init(key)
    batch = make_batch(cfg, key)
    from repro.optim import sgd
    st = sgd.init(params)
    step = jax.jit(sgd.make_train_step(model.loss, 0.1))
    l0 = None
    for _ in range(10):
        st, m = step(st, batch)
        if l0 is None:
            l0 = float(m["loss"])
    assert float(m["loss"]) < l0, (family, l0, float(m["loss"]))


def test_moe_routing_load_balance(key):
    """Aux loss is >= 1 * weight at perfect balance and grows with skew."""
    cfg = FAMILY_CONFIGS["moe"]
    model = build_model(cfg)
    params = model.init(key)
    batch = make_batch(cfg, key)
    _, metrics = model.loss(params, batch)
    assert float(metrics["aux"]) >= 0.0


def test_sliding_window_masks_out_far_context(key):
    """With window w, logits at position t do not depend on tokens < t - w."""
    import dataclasses
    cfg = dataclasses.replace(FAMILY_CONFIGS["dense"], sliding_window=4)
    model = build_model(cfg)
    params = model.init(key)
    t1 = jax.random.randint(key, (1, 16), 0, cfg.vocab_size)
    t2 = t1.at[:, 0].set((t1[:, 0] + 7) % cfg.vocab_size)  # perturb far past
    l1, _ = model.apply(params, {"tokens": t1, "labels": t1})
    l2, _ = model.apply(params, {"tokens": t2, "labels": t2})
    # last position attends to [12..15]; token 0 cannot influence it
    np.testing.assert_allclose(np.asarray(l1[0, -1]), np.asarray(l2[0, -1]),
                               rtol=1e-5, atol=1e-5)


def test_moe_grouped_dispatch_matches_flat(key):
    """GShard-style grouped dispatch (moe_groups>1) must be numerically
    identical to the flat path at drop-free capacity (§Perf lever)."""
    import dataclasses
    from repro.models import moe as moe_mod
    cfg = dataclasses.replace(FAMILY_CONFIGS["moe"], num_shared_experts=0)
    params = moe_mod.init_moe_params(key, cfg)
    x = jax.random.normal(key, (2, 16, cfg.d_model))
    flat, _ = moe_mod.moe_forward(params, cfg, x)
    gcfg = dataclasses.replace(cfg, moe_groups=4)
    from repro.utils.compat import use_mesh
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with use_mesh(mesh):
        grouped, _ = jax.jit(
            lambda p, x: moe_mod.moe_forward(p, gcfg, x))(params, x)
    np.testing.assert_allclose(np.asarray(grouped), np.asarray(flat),
                               rtol=1e-5, atol=1e-6)
