"""The telemetry subsystem (repro/obs): registry merge associativity,
histogram percentile exactness at bucket bounds, event-schema
round-trip, trace-span nesting, engine latency histograms, checkpoint
counter resume, pod-launcher merging, and the instrumentation-overhead
guard.

Tier-1 runs the host-side unit coverage; the train-driver integration
runs and the overhead guard ride the slow lane (and the CI obs lane,
which runs this file with the tier-1 filter overridden).
"""
import importlib.util
import json
import os
import time
import types

import numpy as np
import pytest

from repro.obs import (KINDS, SCHEMA_VERSION, EventSink, Histogram,
                       NULL_SPAN, Registry, Tracer, merge_snapshots,
                       read_events, series_key, snapshot_summaries,
                       validate_event)

_REPORT_PATH = os.path.join(os.path.dirname(__file__), "..",
                            "benchmarks", "obs_report.py")
_spec = importlib.util.spec_from_file_location("obs_report", _REPORT_PATH)
obs_report = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(obs_report)


# ------------------------------------------------------------------
# metrics registry
# ------------------------------------------------------------------

def test_histogram_percentiles_exact_at_bucket_bounds():
    """Observations AT a bound land in that bound's bucket (<=
    semantics), so percentile() is exact for boundary-valued data."""
    h = Histogram("lat", {}, bounds=(1.0, 2.0, 5.0, 10.0))
    for v, n in ((1.0, 50), (2.0, 30), (5.0, 15), (10.0, 4)):
        h.observe(v, n=n)
    h.observe(99.0)                       # overflow bucket
    assert h.count == 100
    assert h.percentile(50) == 1.0        # rank 50 = last of bucket 0
    assert h.percentile(51) == 2.0
    assert h.percentile(95) == 5.0
    assert h.percentile(99) == 10.0
    assert h.percentile(100) == 99.0      # overflow reports observed max
    s = h.summary()
    assert s["min"] == 1.0 and s["max"] == 99.0
    assert s["mean"] == pytest.approx((50 + 60 + 75 + 40 + 99) / 100)


def test_histogram_weighted_observe_and_bounds_validation():
    h = Histogram("h", {}, bounds=(10.0, 20.0))
    h.observe(15.0, n=7)
    assert h.count == 7 and h.bucket_counts == [0, 7, 0]
    h.observe(15.0, n=0)                  # no-op
    assert h.count == 7
    with pytest.raises(ValueError):
        Histogram("bad", {}, bounds=(5.0, 5.0))
    with pytest.raises(ValueError):
        Histogram("bad", {}, bounds=(5.0, 1.0))


def test_series_key_and_labeled_series_distinct():
    assert series_key("a", {}) == "a"
    assert series_key("a", {"r": 1, "b": "x"}) == "a{b=x,r=1}"
    r = Registry()
    r.counter("loss", replica=0).inc(1)
    r.counter("loss", replica=1).inc(2)
    snap = r.snapshot()
    totals = {series_key(e["name"], e["labels"]): e["total"]
              for e in snap["counters"]}
    assert totals == {"loss{replica=0}": 1, "loss{replica=1}": 2}


def _process_registry(seed: int) -> dict:
    """One simulated pod process's registry snapshot."""
    r = Registry()
    r.counter("steps").inc(10 * seed)
    r.counter("tokens", shard=seed % 2).inc(seed)
    g = r.gauge("loss")
    for i in range(seed):                 # later processes update more
        g.set(7.0 - seed - 0.1 * i)
    h = r.histogram("step_ms", bounds=(1.0, 10.0, 100.0))
    for v in (0.5 * seed, 5.0, 50.0 * seed):
        h.observe(v)
    return r.snapshot()


def test_merge_is_associative_and_commutative_across_processes():
    a, b, c = (_process_registry(s) for s in (1, 2, 3))
    m_left = merge_snapshots(merge_snapshots(a, b), c)
    m_right = merge_snapshots(a, merge_snapshots(b, c))
    m_flat = merge_snapshots(a, b, c)
    m_perm = merge_snapshots(c, a, b)
    assert m_left == m_right == m_flat == m_perm
    totals = {series_key(e["name"], e["labels"]): e["total"]
              for e in m_flat["counters"]}
    assert totals["steps"] == 60
    assert totals["tokens{shard=0}"] == 2 and totals["tokens{shard=1}"] == 4
    # gauge: the (updates, value)-max — process 3 updated most
    (gauge,) = m_flat["gauges"]
    assert gauge["updates"] == 3 and gauge["value"] == pytest.approx(3.8)
    (hist,) = m_flat["hists"]
    assert hist["count"] == 9
    assert hist["min"] == 0.5 and hist["max"] == 150.0
    # summaries render every merged series
    summ = snapshot_summaries(m_flat)
    assert summ["step_ms"]["count"] == 9 and summ["steps"]["total"] == 60


def test_merge_rejects_mismatched_histogram_bounds():
    r1, r2 = Registry(), Registry()
    r1.histogram("h", bounds=(1.0, 2.0)).observe(1.0)
    r2.histogram("h", bounds=(1.0, 3.0)).observe(1.0)
    with pytest.raises(ValueError, match="mismatched bounds"):
        merge_snapshots(r1.snapshot(), r2.snapshot())


def test_counter_stamp_resumes_monotonically(tmp_path):
    """Checkpoint sidecar stamp -> restore_counters: totals continue
    from the stamp instead of restarting at zero."""
    from repro.checkpoint import checkpoint as ckpt
    r = Registry()
    r.counter("train.steps").inc(40)
    r.counter("train.tokens").inc(4096)
    path = str(tmp_path / "st.npz")
    ckpt.save(path, {"w": np.zeros((3,), np.float32)}, step=40,
              algo="parle", metrics=r.counter_stamp())
    r2 = Registry()
    r2.restore_counters(ckpt.saved_metrics(path))
    r2.counter("train.steps").inc(10)
    assert r2.counter("train.steps").total == 50
    assert r2.counter("train.tokens").total == 4096
    # sidecar-less / pre-stamp checkpoints restore as empty
    assert ckpt.saved_metrics(str(tmp_path / "missing.npz")) == []
    r3 = Registry()
    r3.restore_counters([])
    assert r3.snapshot()["counters"] == []


# ------------------------------------------------------------------
# versioned JSONL events
# ------------------------------------------------------------------

def test_event_sink_roundtrip(tmp_path):
    path = str(tmp_path / "m.jsonl")
    sink = EventSink(path)
    sink.emit("train_progress", step=1, round=0, loss=6.9, wall_s=0.1,
              diag={"overlap": 0.99}, extra="fine")
    sink.emit("staleness_flush", step=10, flush_ms=1.25)
    sink.emit("metrics_snapshot", snapshot=Registry().snapshot())
    sink.close()
    evs = read_events(path)               # re-validates every line
    assert [e["kind"] for e in evs] == ["train_progress",
                                        "staleness_flush",
                                        "metrics_snapshot"]
    assert all(e["v"] == SCHEMA_VERSION for e in evs)
    assert evs[0]["extra"] == "fine"      # extra fields survive


def test_event_validation_rejects():
    sink = EventSink(None)                # validate-only
    with pytest.raises(ValueError, match="unknown event kind"):
        sink.emit("no_such_kind", x=1)
    with pytest.raises(ValueError, match="missing required field"):
        sink.emit("train_progress", step=1)
    with pytest.raises(ValueError, match="has type"):
        sink.emit("checkpoint", step="one", path="p")
    with pytest.raises(ValueError, match="is a bool"):
        sink.emit("pod_step", step=True, loss=1.0, proc=0)
    with pytest.raises(ValueError, match="schema version"):
        validate_event({"v": 999, "kind": "note", "ts": 0.0, "msg": "x"})
    assert set(KINDS) >= {"train_progress", "train_final", "serve_summary",
                          "pod_merged", "metrics_snapshot"}


def test_read_events_names_offending_line(tmp_path):
    path = str(tmp_path / "bad.jsonl")
    good = {"v": SCHEMA_VERSION, "kind": "note", "ts": 1.0, "msg": "ok"}
    with open(path, "w") as f:
        f.write(json.dumps(good) + "\n")
        f.write(json.dumps({"v": SCHEMA_VERSION, "kind": "bogus",
                            "ts": 1.0}) + "\n")
    with pytest.raises(ValueError, match=r"bad\.jsonl:2"):
        read_events(path)


# ------------------------------------------------------------------
# tracing
# ------------------------------------------------------------------

def test_tracer_nesting_depth_and_chrome_format(tmp_path):
    tr = Tracer(enabled=True, collect=True, pid=3, process_name="t")
    with tr.span("outer", cat="train", round=1):
        time.sleep(0.001)
        with tr.span("inner_a"):
            time.sleep(0.001)
        with tr.span("inner_b"):
            time.sleep(0.001)
    with tr.span("sibling"):
        pass
    chrome = tr.to_chrome()
    path = str(tmp_path / "t.json")
    tr.save(path)
    with open(path) as f:
        assert json.load(f) == chrome
    xs = obs_report.validate_trace(chrome)     # containment + depth check
    by_name = {e["name"]: e for e in xs}
    assert by_name["outer"]["args"]["depth"] == 0
    assert by_name["inner_a"]["args"]["depth"] == 1
    assert by_name["sibling"]["args"]["depth"] == 0
    assert by_name["outer"]["args"]["round"] == 1
    # children contained in the parent; siblings ordered
    o, ia, ib = (by_name[n] for n in ("outer", "inner_a", "inner_b"))
    assert o["ts"] <= ia["ts"] and ia["ts"] + ia["dur"] <= o["ts"] + o["dur"]
    assert ia["ts"] + ia["dur"] <= ib["ts"] + 1.0
    meta = [e for e in chrome["traceEvents"] if e["ph"] == "M"]
    assert meta[0]["args"]["name"] == "t" and meta[0]["pid"] == 3


def test_disabled_and_collectless_tracers():
    off = Tracer(enabled=False)
    assert off.span("x") is NULL_SPAN
    with off.span("x") as sp:
        sp.block(object())                # no-ops, no jax touched
        sp.set(a=1)
    assert off.events == [] and NULL_SPAN.dur_s == 0.0
    # metrics-only mode: spans time themselves but retain no buffer
    quiet = Tracer(enabled=True, collect=False)
    with quiet.span("y") as sp:
        time.sleep(0.001)
    assert sp.dur_s > 0 and quiet.events == []


def test_span_block_waits_for_jax_value():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    tr = Tracer(enabled=True)
    with tr.span("compute") as sp:
        x = jnp.ones((256, 256)) @ jnp.ones((256, 256))
        sp.block(x)
    assert sp.dur_s > 0
    assert tr.events[-1]["name"] == "compute"


def test_validate_trace_rejects_malformed():
    with pytest.raises(ValueError, match="traceEvents"):
        obs_report.validate_trace({"events": []})
    with pytest.raises(ValueError, match="numeric"):
        obs_report.validate_trace(
            {"traceEvents": [{"ph": "X", "name": "a", "ts": "now",
                              "dur": 1}]})
    # partial overlap on one track is not a nesting
    bad = {"traceEvents": [
        {"ph": "X", "name": "a", "ts": 0.0, "dur": 100.0, "pid": 0,
         "tid": 0},
        {"ph": "X", "name": "b", "ts": 50.0, "dur": 100.0, "pid": 0,
         "tid": 0}]}
    with pytest.raises(ValueError, match="without being contained"):
        obs_report.validate_trace(bad)
    # recorded depth contradicting containment
    bad_depth = {"traceEvents": [
        {"ph": "X", "name": "a", "ts": 0.0, "dur": 100.0, "pid": 0,
         "tid": 0, "args": {"depth": 0}},
        {"ph": "X", "name": "b", "ts": 10.0, "dur": 20.0, "pid": 0,
         "tid": 0, "args": {"depth": 0}}]}
    with pytest.raises(ValueError, match="depth"):
        obs_report.validate_trace(bad_depth)


# ------------------------------------------------------------------
# engine latency histograms (dense tier-1 path)
# ------------------------------------------------------------------

def test_engine_reports_latency_histograms(key):
    from conftest import FAMILY_CONFIGS
    from repro.models.model import build_model
    from repro.serving import Engine
    cfg = FAMILY_CONFIGS["dense"]
    params = build_model(cfg).init(key)
    reg, tracer = Registry(), Tracer(enabled=True, collect=True)
    eng = Engine(cfg, params, num_slots=2, max_len=32, decode_chunk=4,
                 registry=reg, tracer=tracer)
    gen = 6
    for i in range(3):
        toks = np.asarray(
            np.arange(5 + i) % cfg.vocab_size, np.int32)
        eng.submit(toks, max_new_tokens=gen)
    eng.run()
    tp = eng.throughput()
    # new per-request fields, backed by the registry histograms
    assert tp["ttft_ms"]["count"] == 3
    assert tp["completion_ms"]["count"] == 3
    assert tp["itl_ms"]["count"] == 3 * (gen - 1)   # first token = prefill
    assert tp["completion_ms"]["p50"] >= tp["ttft_ms"]["min"] >= 0
    assert tp["counters"] == {"requests": 3, "admitted": 3, "requeued": 0,
                              "backpressure": 0, "finished": 3,
                              "deadline_exceeded": 0}
    # pre-existing aggregate keys stay (aliases for one release)
    for old in ("compile_s", "prefill_tokens_per_s", "decode_tokens_per_s",
                "slot_utilization", "wasted_decode_tokens"):
        assert old in tp
    # the engine's spans validate as a Chrome trace, compile separated
    xs = obs_report.validate_trace(tracer.to_chrome())
    cats = {e["cat"] for e in xs}
    assert "compile" in cats and "decode" in cats and "prefill" in cats


@pytest.mark.slow
def test_engine_paged_backpressure_and_pool_gauges(key):
    from conftest import FAMILY_CONFIGS
    from repro.models.model import build_model
    from repro.serving import Engine
    cfg = FAMILY_CONFIGS["dense"]
    params = build_model(cfg).init(key)
    reg = Registry()
    # slots outnumber the pool: each request reserves 2 pages, 5 usable
    # pages admit two — the third hits page backpressure, not a slot
    # limit.  Distinct prompts so prefix sharing can't shrink demand.
    eng = Engine(cfg, params, num_slots=3, max_len=32, decode_chunk=4,
                 paged=True, page_size=8, num_pages=6, prefill_chunk=16,
                 registry=reg)
    for i in range(3):
        eng.submit(np.asarray((np.arange(6) + 7 * i) % cfg.vocab_size,
                              np.int32),
                   max_new_tokens=6)
    eng.run()
    assert reg.counter("serve.backpressure").total > 0
    assert (reg.counter("serve.requeued").total
            == reg.counter("serve.backpressure").total)
    assert reg.counter("serve.finished").total == 3
    snap = {series_key(g["name"], g["labels"]): g["value"]
            for g in reg.snapshot()["gauges"]}
    assert "serve.pages_free" in snap and "serve.page_occupancy" in snap
    assert 0.0 <= snap["serve.page_occupancy"] <= 1.0


# ------------------------------------------------------------------
# pod launcher merge (host-side, no processes spawned)
# ------------------------------------------------------------------

def test_dist_run_merges_worker_snapshots(tmp_path):
    from repro.launch.dist_run import _merge_pod_obs, build_argparser
    ap = build_argparser()
    mpath = str(tmp_path / "pod.jsonl")
    tpath = str(tmp_path / "pod_trace.json")
    args = ap.parse_args(["--nproc", "2", "--metrics-out", mpath,
                          "--trace-out", tpath])
    for i in (0, 1):
        r = Registry()
        r.counter("pod.steps").inc(6)
        r.histogram("pod.step_ms", bounds=(10.0, 100.0)).observe(50.0, n=6)
        sink = EventSink(f"{mpath}.worker{i}")
        sink.emit("pod_step", step=1, loss=6.5, proc=i)
        sink.emit("metrics_snapshot", snapshot=r.snapshot())
        sink.close()
        Tracer(enabled=True, pid=i,
               process_name=f"pod-worker{i}").save(f"{tpath}.worker{i}")
    _merge_pod_obs(args)
    (merged,) = read_events(mpath)
    assert merged["kind"] == "pod_merged" and merged["processes"] == 2
    totals = {c["name"]: c["total"] for c in merged["snapshot"]["counters"]}
    assert totals["pod.steps"] == 12
    (hist,) = merged["snapshot"]["hists"]
    assert hist["count"] == 12
    with open(tpath) as f:
        trace = json.load(f)
    assert {e["pid"] for e in trace["traceEvents"]} == {0, 1}


# ------------------------------------------------------------------
# train-driver integration (slow lane / CI obs lane)
# ------------------------------------------------------------------

_TRAIN_ARGS = ["--smoke", "--replicas", "2", "--batch", "1", "--seq", "8",
               "--log-every", "2"]


@pytest.mark.slow
def test_train_fused_round_trace_and_unified_events(tmp_path):
    from repro.launch import train
    m_fused = str(tmp_path / "fused.jsonl")
    t_fused = str(tmp_path / "fused_trace.json")
    train.main(_TRAIN_ARGS + ["--steps", "4", "--L", "2", "--round-fused",
                              "--sync-overlap",
                              "--metrics-out", m_fused,
                              "--trace-out", t_fused])
    evs = read_events(m_fused)
    kinds = [e["kind"] for e in evs]
    assert kinds.count("train_progress") == 2
    assert "staleness_flush" in kinds and "train_final" in kinds
    assert kinds[-1] == "metrics_snapshot"
    snap = evs[-1]["snapshot"]
    totals = {c["name"]: c["total"] for c in snap["counters"]}
    assert totals["train.steps"] == 4 and totals["train.rounds"] == 2
    assert totals["train.staleness_flushes"] == 1
    hists = {h["name"]: h for h in snap["hists"]}
    assert hists["train.round_ms"]["count"] == 2

    with open(t_fused) as f:
        xs = obs_report.validate_trace(json.load(f))
    rounds = sorted((e for e in xs if e["name"] == "round"),
                    key=lambda e: e["ts"])
    compiles = [e for e in xs if e["cat"] == "compile"]
    flushes = [e for e in xs if e["name"] == "sync_flush"]
    assert len(rounds) == 2 and compiles and flushes
    # compile strictly precedes steady state; rounds are ordered
    assert max(c["ts"] + c["dur"] for c in compiles) <= rounds[0]["ts"]
    assert rounds[0]["ts"] + rounds[0]["dur"] <= rounds[1]["ts"]
    assert [r["args"]["round"] for r in rounds] == [1, 2]

    # SAME progress key set from the per-step driver (the two emit
    # sites were inconsistent before the unified schema)
    m_step = str(tmp_path / "step.jsonl")
    train.main(_TRAIN_ARGS + ["--steps", "2", "--L", "2",
                              "--metrics-out", m_step])
    step_prog = [e for e in read_events(m_step)
                 if e["kind"] == "train_progress"]
    fused_prog = [e for e in evs if e["kind"] == "train_progress"]
    assert step_prog and set(step_prog[0]) == set(fused_prog[0])


@pytest.mark.slow
def test_train_checkpoint_carries_counter_stamp(tmp_path):
    from repro.checkpoint import checkpoint as ckpt
    from repro.launch import train
    ckdir = str(tmp_path / "ck")
    train.main(_TRAIN_ARGS + ["--steps", "4", "--L", "2",
                              "--checkpoint-dir", ckdir,
                              "--checkpoint-every", "2"])
    stamp = ckpt.saved_metrics(f"{ckdir}/step000004.npz")
    totals = {c["name"]: c["total"] for c in stamp}
    assert totals["train.steps"] == 4
    # resume: counters continue from the stamp (4 + 2 more steps)
    m_out = str(tmp_path / "resumed.jsonl")
    train.main(_TRAIN_ARGS + ["--steps", "2", "--L", "2",
                              "--resume", f"{ckdir}/step000004.npz",
                              "--metrics-out", m_out])
    snap = read_events(m_out)[-1]["snapshot"]
    totals = {c["name"]: c["total"] for c in snap["counters"]}
    assert totals["train.steps"] == 6


# ------------------------------------------------------------------
# overhead guard: instrumented fused round within noise of bare
# ------------------------------------------------------------------

@pytest.mark.slow
def test_instrumented_round_within_noise_of_bare():
    """Full per-round telemetry (span ending on block_until_ready +
    counters + histogram) on the pinned-scale fused round must stay
    within noise of the uninstrumented round.  Interleaved min-of-trials
    keeps machine-load noise symmetric; the bound is the BENCH
    acceptance ratio (1.02) plus a small absolute cushion for CI jitter
    on a ~10 ms round."""
    import jax
    from repro.configs.base import ModelConfig, ParleConfig
    from repro.core import registry as algo_registry
    from repro.core.parle import dealias_state
    from repro.data.synthetic import TokenStream, make_round_batch_fn
    from repro.models.model import build_model

    mcfg = ModelConfig(name="obs-bench", family="dense", num_layers=2,
                       d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                       vocab_size=512, head_dim=16)
    pcfg = ParleConfig(n_replicas=2, L=5, batches_per_epoch=5)
    algo = algo_registry.get("parle")
    model = build_model(mcfg)
    state = dealias_state(
        algo.init(model.init(jax.random.PRNGKey(0)), pcfg))
    stream = TokenStream(vocab_size=512, seq_len=16, batch_size=1, seed=0)
    stage = make_round_batch_fn(stream, pcfg.L, 1, 2)
    round_c = algo.make_round_fn(model.loss, pcfg) \
        .lower(state, stage(0)).compile()
    reg, tracer = Registry(), Tracer(enabled=True, collect=True)

    def trial(rs, k, instrumented):
        nxt = stage(0)
        jax.block_until_ready(nxt)
        t0 = time.perf_counter()
        for r in range(k):
            cur, nxt = nxt, None
            if instrumented:
                with tracer.span("round", round=r) as sp:
                    rs, m = round_c(rs, cur)
                    nxt = stage((r + 1) * pcfg.L)
                    sp.block(m)
                reg.counter("train.steps").inc(pcfg.L)
                reg.counter("train.rounds").inc()
                reg.histogram("train.round_ms").observe(sp.dur_s * 1e3)
            else:
                rs, m = round_c(rs, cur)
                nxt = stage((r + 1) * pcfg.L)
        jax.block_until_ready(m)
        return rs, (time.perf_counter() - t0) / k

    state, _ = trial(state, 3, False)     # warmup (donation chain)
    state, _ = trial(state, 3, True)
    bare, inst = [], []
    for _ in range(5):                    # interleaved: noise hits both
        state, dt = trial(state, 6, False)
        bare.append(dt)
        state, dt = trial(state, 6, True)
        inst.append(dt)
    bare_s, inst_s = min(bare), min(inst)
    # 1.02x (the BENCH acceptance) + 300 µs/round absolute cushion
    assert inst_s <= bare_s * 1.02 + 300e-6, (
        f"instrumented round {inst_s * 1e3:.2f} ms vs bare "
        f"{bare_s * 1e3:.2f} ms (trials: {inst} vs {bare})")
